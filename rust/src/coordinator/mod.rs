//! Serving coordinator (Table 7's end-to-end path): generation sessions
//! with iteration-level scheduling, token streaming, cancellation, and
//! typed errors. DESIGN.md §6 documents the architecture.
//!
//! * [`request`] — request/sampling types, the [`Event`] stream protocol,
//!   the [`ServeError`] taxonomy, and [`ServeMetrics`].
//! * [`engine`] — the [`DecodeBackend`] trait plus the PJRT and
//!   Rust-native backends (the latter needs no artifacts), including the
//!   no-KV re-prefill mode the paper contrasts (Table 7 "Use KV Cache").
//! * [`scheduler`] — per-lane [`GenSession`] slots, bounded admission,
//!   coalescing, deadline sweeps, and one-decode-step-per-iteration
//!   continuous batching.
//! * [`server`] — worker-thread server: `submit` returns a
//!   [`StreamHandle`] of token events with mid-generation `cancel()`;
//!   `spawn_speculative` installs a compressed-variant
//!   [`crate::runtime::DraftEngine`] for self-speculative decoding
//!   (DESIGN.md §11).
//! * [`router`] — the multi-replica tier (DESIGN.md §12): prefix-aware
//!   placement over a fleet of [`Server`] replicas, load-aware spill,
//!   probe-driven health states, draining, and fleet-level
//!   [`RouterMetrics`] with a *global* prefix-hit rate.
//! * [`clock`] — the injectable time source ([`SystemClock`] /
//!   [`ManualClock`]) behind every scheduling-policy timestamp, so
//!   tests and benchmarks can drive timing deterministically.

pub mod clock;
pub mod engine;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use clock::{system_clock, Clock, ManualClock, SystemClock};
pub use engine::{
    AdmitVerdict, DecodeBackend, GenerationMode, KvLifeConfig, NativeBackend, PagedKvParams,
    PjrtBackend, StepInput, StepResult,
};
pub use request::{
    EngineFault, Event, FinishReason, GenRequest, GenStats, Priority, SamplingParams, ServeError,
    ServeMetrics,
};
pub use router::{
    KillSwitch, PlacementPolicy, ReplicaState, Router, RouterConfig, RouterMetrics,
    RouterStreamHandle,
};
pub use scheduler::{GenSession, Scheduler, SchedulerConfig};
pub use server::{ProbeReply, Server, StreamHandle};
