//! Decode backends for the session scheduler.
//!
//! The scheduler (DESIGN.md §6) drives generation one *iteration* at a
//! time over a set of lanes; a [`DecodeBackend`] owns the per-lane model
//! state. Two implementations:
//!
//! * [`PjrtBackend`] — prefill/decode through an AOT artifact pair via
//!   [`ModelRunner`], with per-lane KV state in [`LaneKv`]. Lanes map to
//!   batch rows of the static-batch decode artifact; lanes that share a
//!   sequence position decode in one PJRT call.
//! * [`NativeBackend`] — the from-scratch Rust forward path, one
//!   [`KvCache`] per lane. No artifacts required: this is the serving
//!   path CI exercises and the fallback `pifa serve` uses when PJRT is
//!   unavailable.
//!
//! Both honour [`GenerationMode::NoKvCache`] (full re-prefill per token),
//! the mode 2:4-sparse and hybrid `lowrank-s24` models are forced into
//! when the sparse kernel cannot run the cache ops (Table 7's
//! "Use KV Cache: No" rows).

use crate::linalg::Mat;
use crate::model::transformer::{KvCache, Transformer};
use crate::runtime::exec::{literal_f32_view, KvState, LaneKv, ModelRunner};
use crate::runtime::kernels::pool;
use crate::runtime::Engine;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Whether decode reuses the KV cache (Table 7's "Use KV Cache" axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GenerationMode {
    /// Prefill once, then one decode step per token (cache reused).
    KvCache,
    /// Re-run the full prefill for every generated token — the paper's
    /// no-cache row (and what 2:4 sparse models are forced into when the
    /// sparse kernel can't run the cache ops).
    NoKvCache,
}

/// One lane's contribution to a shared decode iteration.
pub struct StepInput<'a> {
    /// Lane index (stable for the session's lifetime).
    pub lane: usize,
    /// The most recently sampled token (to be fed this step).
    pub token: usize,
    /// Full sequence so far: prompt + generated, `token` last. No-KV
    /// backends re-prefill this; KV backends only consume `token`.
    pub seq: &'a [usize],
}

/// Per-lane generation state owned by a backend. `prefill` claims a
/// lane, `step` advances any subset of claimed lanes by one token, and
/// `release` frees a lane for reuse (cancel / finish).
pub trait DecodeBackend {
    /// Number of concurrent lanes this backend can hold.
    fn lanes(&self) -> usize;
    /// Maximum total sequence length (prompt + generated) a lane holds.
    fn max_seq(&self) -> usize;
    /// Maximum prompt length accepted by `prefill`.
    fn max_prompt(&self) -> usize {
        self.max_seq()
    }
    /// Run the prompt through the model on `lane`; returns the logits row
    /// for the final prompt position.
    fn prefill(&mut self, lane: usize, prompt: &[usize]) -> Result<Vec<f32>>;
    /// Advance the given lanes one token; returns one logits row per
    /// input, in input order.
    fn step(&mut self, inputs: &[StepInput<'_>]) -> Result<Vec<Vec<f32>>>;
    /// Free a lane's state so a queued session can claim it.
    fn release(&mut self, lane: usize);
    /// Diagnostic label.
    fn name(&self) -> &'static str {
        "backend"
    }
}

/// Pure-Rust backend: one [`KvCache`] per lane over a [`Transformer`].
pub struct NativeBackend {
    model: Transformer,
    mode: GenerationMode,
    caches: Vec<Option<KvCache>>,
}

/// Per-lane step job (token + owned cache) handed to a pool job.
type LaneJob = Mutex<Option<(usize, KvCache)>>;
/// Per-lane step result (logits + the cache handed back).
type LaneDone = Mutex<Option<(Mat<f32>, KvCache)>>;

impl NativeBackend {
    pub fn new(model: Transformer, mode: GenerationMode, lanes: usize) -> Self {
        // Spawn the kernel pool now so the first decode token does not
        // pay the worker start-up cost.
        pool::prewarm();
        Self { model, mode, caches: (0..lanes.max(1)).map(|_| None).collect() }
    }
}

impl DecodeBackend for NativeBackend {
    fn lanes(&self) -> usize {
        self.caches.len()
    }

    fn max_seq(&self) -> usize {
        self.model.cfg.max_seq
    }

    fn prefill(&mut self, lane: usize, prompt: &[usize]) -> Result<Vec<f32>> {
        if lane >= self.caches.len() {
            bail!("lane {lane} out of range ({} lanes)", self.caches.len());
        }
        if prompt.is_empty() || prompt.len() > self.max_prompt() {
            bail!("prompt length {} not in 1..={}", prompt.len(), self.max_prompt());
        }
        match self.mode {
            GenerationMode::KvCache => {
                let mut cache = KvCache::new(&self.model.cfg);
                let mut logits = None;
                for &t in prompt {
                    logits = Some(self.model.decode_step(t, &mut cache));
                }
                self.caches[lane] = Some(cache);
                Ok(logits.context("empty prompt")?.row(0).to_vec())
            }
            GenerationMode::NoKvCache => {
                let logits = self.model.forward(prompt, None);
                Ok(logits.row(prompt.len() - 1).to_vec())
            }
        }
    }

    /// Lanes are independent, so one shared iteration can fan the
    /// per-lane work across the kernel pool (the kernels inside a pool
    /// job run inline — nested pool calls do not re-enter). KV-cache
    /// decode steps are single-token GEMVs, usually below the banding
    /// threshold, so lane-level parallelism is the only parallelism
    /// available and is always used; no-KV steps are prefill-sized
    /// forwards whose inner GEMMs band across the pool themselves, so
    /// lanes fan out only when there are at least as many of them as
    /// pool slots. All validation happens up front so the parallel
    /// section is infallible.
    fn step(&mut self, inputs: &[StepInput<'_>]) -> Result<Vec<Vec<f32>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        match self.mode {
            GenerationMode::KvCache => {
                let mut seen = vec![false; self.caches.len()];
                for inp in inputs {
                    let cache = self
                        .caches
                        .get(inp.lane)
                        .and_then(Option::as_ref)
                        .with_context(|| format!("lane {} has no prefilled cache", inp.lane))?;
                    if cache.len >= cache.capacity {
                        bail!("lane {} KV cache full at {}", inp.lane, cache.len);
                    }
                    if seen[inp.lane] {
                        bail!("lane {} appears twice in one iteration", inp.lane);
                    }
                    seen[inp.lane] = true;
                }
                // Move each lane's cache into its job slot; jobs own it for
                // the duration of the scope and hand it back with the
                // logits.
                let jobs: Vec<LaneJob> = inputs
                    .iter()
                    .map(|inp| Mutex::new(Some((inp.token, self.caches[inp.lane].take().unwrap()))))
                    .collect();
                let done: Vec<LaneDone> = inputs.iter().map(|_| Mutex::new(None)).collect();
                let model = &self.model;
                pool::scope_run(inputs.len(), |i| {
                    let (token, mut cache) = jobs[i].lock().unwrap().take().unwrap();
                    let logits = model.decode_step(token, &mut cache);
                    *done[i].lock().unwrap() = Some((logits, cache));
                });
                let mut out = Vec::with_capacity(inputs.len());
                for (inp, slot) in inputs.iter().zip(done) {
                    let (logits, cache) =
                        slot.into_inner().unwrap().context("lane step produced no result")?;
                    self.caches[inp.lane] = Some(cache);
                    out.push(logits.row(0).to_vec());
                }
                Ok(out)
            }
            GenerationMode::NoKvCache => {
                for inp in inputs {
                    if inp.seq.is_empty() || inp.seq.len() > self.model.cfg.max_seq {
                        bail!("sequence length {} exceeds max_seq", inp.seq.len());
                    }
                }
                // Full re-prefill every step — the no-cache cost. Each
                // lane's forward is prefill-sized, so its inner GEMMs can
                // use the whole pool; fanning lanes out would serialize
                // them (nested pool calls run inline). Only go
                // lane-parallel when there are enough lanes to cover the
                // machine on their own.
                let done: Vec<Mutex<Option<Mat<f32>>>> =
                    inputs.iter().map(|_| Mutex::new(None)).collect();
                let model = &self.model;
                if inputs.len() >= pool::max_parallelism() {
                    pool::scope_run(inputs.len(), |i| {
                        *done[i].lock().unwrap() = Some(model.forward(inputs[i].seq, None));
                    });
                } else {
                    for (inp, slot) in inputs.iter().zip(done.iter()) {
                        *slot.lock().unwrap() = Some(model.forward(inp.seq, None));
                    }
                }
                inputs
                    .iter()
                    .zip(done)
                    .map(|(inp, slot)| {
                        let logits =
                            slot.into_inner().unwrap().context("lane step produced no result")?;
                        Ok(logits.row(inp.seq.len() - 1).to_vec())
                    })
                    .collect()
            }
        }
    }

    fn release(&mut self, lane: usize) {
        if let Some(c) = self.caches.get_mut(lane) {
            *c = None;
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// PJRT backend: lanes are batch rows of the static-batch decode
/// artifact; per-lane KV lives in a [`LaneKv`] so a single lane can be
/// re-prefetched or reset without rebuilding the merged `(L,B,S,d)`
/// cache. Lanes at the same sequence position share one decode call.
pub struct PjrtBackend {
    pjrt: Engine,
    runner: ModelRunner,
    mode: GenerationMode,
    kv: LaneKv,
}

impl PjrtBackend {
    pub fn new(pjrt: Engine, runner: ModelRunner, mode: GenerationMode) -> Self {
        let kv = runner.lane_kv();
        Self { pjrt, runner, mode, kv }
    }
}

impl DecodeBackend for PjrtBackend {
    fn lanes(&self) -> usize {
        self.runner.batch.max(1)
    }

    fn max_seq(&self) -> usize {
        match self.mode {
            GenerationMode::KvCache => self.runner.max_seq,
            // Without the cache every step re-prefills the whole
            // sequence, so the prefill artifact's window is the cap.
            GenerationMode::NoKvCache => self.runner.prefill_seq,
        }
    }

    fn max_prompt(&self) -> usize {
        self.runner.prefill_seq
    }

    fn prefill(&mut self, lane: usize, prompt: &[usize]) -> Result<Vec<f32>> {
        if lane >= self.lanes() {
            bail!("lane {lane} out of range ({} lanes)", self.lanes());
        }
        let (logits, kvs) = self.runner.prefill(&mut self.pjrt, prompt)?;
        if self.mode == GenerationMode::KvCache {
            // Borrowed views: no full-cache copies on the claim path.
            let k = literal_f32_view(&kvs.k)?;
            let v = literal_f32_view(&kvs.v)?;
            self.kv.write_lane(lane, k, v, prompt.len())?;
        }
        Ok(self.runner.logits_at(&logits, prompt.len() - 1))
    }

    fn step(&mut self, inputs: &[StepInput<'_>]) -> Result<Vec<Vec<f32>>> {
        match self.mode {
            GenerationMode::NoKvCache => {
                let mut out = Vec::with_capacity(inputs.len());
                for inp in inputs {
                    let (logits, _) = self.runner.prefill(&mut self.pjrt, inp.seq)?;
                    out.push(self.runner.logits_at(&logits, inp.seq.len() - 1));
                }
                Ok(out)
            }
            GenerationMode::KvCache => {
                // Group lanes by shared position: the decode artifact
                // takes one scalar `pos`, so only same-position lanes
                // can share a call. Mixed-length traffic still shares
                // whenever prompts align or converge.
                //
                // Each group pays full-cache host<->literal copies
                // (k/v_literal + absorb_step). With the vendored
                // host-side xla stub this is a plain memcpy; a real
                // device runtime would instead keep the cache resident
                // and materialize single lanes only on prefill/release.
                let mut by_pos: BTreeMap<usize, Vec<(usize, usize, usize)>> = BTreeMap::new();
                for (i, inp) in inputs.iter().enumerate() {
                    if inp.lane >= self.lanes() {
                        bail!("lane {} out of range", inp.lane);
                    }
                    let pos = self.kv.pos[inp.lane];
                    if pos == 0 {
                        bail!("lane {} stepped without prefill", inp.lane);
                    }
                    by_pos.entry(pos).or_default().push((i, inp.lane, inp.token));
                }
                let mut out: Vec<Vec<f32>> = vec![Vec::new(); inputs.len()];
                for (pos, group) in by_pos {
                    if pos >= self.runner.max_seq {
                        bail!("KV cache full at pos {pos}");
                    }
                    let mut tokens = vec![0usize; self.runner.batch];
                    for &(_, lane, token) in &group {
                        tokens[lane] = token;
                    }
                    let state =
                        KvState { k: self.kv.k_literal()?, v: self.kv.v_literal()?, pos };
                    let (rows, new_state) =
                        self.runner.decode_step(&mut self.pjrt, state, &tokens)?;
                    let lanes: Vec<usize> = group.iter().map(|g| g.1).collect();
                    self.kv.absorb_step(&lanes, &new_state.k, &new_state.v, pos)?;
                    for &(i, lane, _) in &group {
                        out[i] = rows[lane].clone();
                    }
                }
                Ok(out)
            }
        }
    }

    fn release(&mut self, lane: usize) {
        self.kv.reset_lane(lane);
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::model::config::ModelConfig;
    use crate::runtime::exec::argmax;

    fn tiny_model(seed: u64) -> Transformer {
        let cfg = ModelConfig::tiny_s();
        let mut rng = Rng::new(seed);
        Transformer::new_random(&cfg, &mut rng)
    }

    /// Greedy-generate through a backend exactly as the scheduler does:
    /// prefill emits token 0, each step emits one more.
    fn backend_greedy(
        backend: &mut dyn DecodeBackend,
        lane: usize,
        prompt: &[usize],
        max_new: usize,
    ) -> Vec<usize> {
        let logits = backend.prefill(lane, prompt).unwrap();
        let mut seq = prompt.to_vec();
        seq.push(argmax(&logits));
        while seq.len() - prompt.len() < max_new {
            let last = *seq.last().unwrap();
            let rows = backend
                .step(&[StepInput { lane, token: last, seq: &seq }])
                .unwrap();
            seq.push(argmax(&rows[0]));
        }
        backend.release(lane);
        seq[prompt.len()..].to_vec()
    }

    #[test]
    fn native_kv_backend_matches_model_generate() {
        let model = tiny_model(411);
        let prompt = vec![3usize, 11, 7, 2];
        let want = model.generate(&prompt, 6);
        let mut be = NativeBackend::new(model, GenerationMode::KvCache, 2);
        assert_eq!(backend_greedy(&mut be, 1, &prompt, 6), want);
    }

    #[test]
    fn native_nokv_matches_kv() {
        let model = tiny_model(412);
        let prompt = vec![9usize, 4, 21];
        let mut kv = NativeBackend::new(model.clone(), GenerationMode::KvCache, 1);
        let mut nokv = NativeBackend::new(model, GenerationMode::NoKvCache, 1);
        let a = backend_greedy(&mut kv, 0, &prompt, 5);
        let b = backend_greedy(&mut nokv, 0, &prompt, 5);
        assert_eq!(a, b, "KV and no-KV must agree on greedy tokens");
    }

    #[test]
    fn native_lanes_are_independent() {
        let model = tiny_model(413);
        let pa = vec![5usize, 17, 100];
        let pb = vec![42usize, 3, 9, 7, 1];
        let want_a = model.generate(&pa, 4);
        let want_b = model.generate(&pb, 4);
        let mut be = NativeBackend::new(model, GenerationMode::KvCache, 2);
        // Interleave the two lanes through shared iterations.
        let la = be.prefill(0, &pa).unwrap();
        let lb = be.prefill(1, &pb).unwrap();
        let mut sa = pa.clone();
        sa.push(argmax(&la));
        let mut sb = pb.clone();
        sb.push(argmax(&lb));
        for _ in 0..3 {
            let rows = be
                .step(&[
                    StepInput { lane: 0, token: *sa.last().unwrap(), seq: &sa },
                    StepInput { lane: 1, token: *sb.last().unwrap(), seq: &sb },
                ])
                .unwrap();
            sa.push(argmax(&rows[0]));
            sb.push(argmax(&rows[1]));
        }
        assert_eq!(&sa[pa.len()..], &want_a[..]);
        assert_eq!(&sb[pb.len()..], &want_b[..]);
    }

    #[test]
    fn native_released_lane_can_be_reclaimed() {
        let model = tiny_model(414);
        let prompt = vec![1usize, 2, 3];
        let want = model.generate(&prompt, 3);
        let mut be = NativeBackend::new(model, GenerationMode::KvCache, 1);
        assert_eq!(backend_greedy(&mut be, 0, &prompt, 3), want);
        // backend_greedy released lane 0; a second session reuses it.
        assert_eq!(backend_greedy(&mut be, 0, &prompt, 3), want);
    }

    #[test]
    fn native_backend_rejects_bad_lanes_and_prompts() {
        let model = tiny_model(415);
        let max = model.cfg.max_seq;
        let mut be = NativeBackend::new(model, GenerationMode::KvCache, 1);
        assert!(be.prefill(7, &[1, 2]).is_err());
        assert!(be.prefill(0, &[]).is_err());
        let too_long = vec![1usize; max + 1];
        assert!(be.prefill(0, &too_long).is_err());
        // Stepping an unprefilled lane is a typed error, not a panic.
        assert!(be.step(&[StepInput { lane: 0, token: 1, seq: &[1] }]).is_err());
    }
}
