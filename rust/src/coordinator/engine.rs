//! Decode backends for the session scheduler.
//!
//! The scheduler (DESIGN.md §6) drives generation one *iteration* at a
//! time over a set of lanes; a [`DecodeBackend`] owns the per-lane model
//! state. Two implementations:
//!
//! * [`PjrtBackend`] — prefill/decode through an AOT artifact pair via
//!   [`ModelRunner`], with per-lane KV state in the paged [`LaneKv`].
//!   Lanes map to batch rows of the static-batch decode artifact; lanes
//!   that share a sequence position decode in one PJRT call.
//! * [`NativeBackend`] — the from-scratch Rust forward path. The default
//!   KV layout is the *paged* block pool (`runtime::kvpool`, DESIGN.md
//!   §8): sessions hold block tables, shared prompt prefixes map the
//!   same physical blocks, and the lane cap comes from the pool size
//!   rather than a fixed constructor argument. The contiguous per-lane
//!   [`KvCache`] layout survives as [`NativeBackend::contiguous`], the
//!   reference the differential suite compares against.
//!
//! Failure granularity: [`DecodeBackend::step`] returns one
//! [`StepResult`] per lane, so a KV bounds failure or pool exhaustion on
//! one lane is a [`StepResult::Fault`] that fails only the offending
//! session — an `Err` from `step` still means the whole engine state is
//! unknown and every in-flight session fails.
//!
//! Both backends honour [`GenerationMode::NoKvCache`] (full re-prefill
//! per token), the mode 2:4-sparse and hybrid `lowrank-s24` models are
//! forced into when the sparse kernel cannot run the cache ops
//! (Table 7's "Use KV Cache: No" rows).

use crate::linalg::Mat;
use crate::model::transformer::{KvCache, KvStoreFull, Transformer};
use crate::runtime::exec::{literal_f32_view, KvState, LaneKv, ModelRunner};
use crate::runtime::kernels::gather::{self, LaneView};
use crate::runtime::kernels::pool;
use crate::runtime::kvlife::{CompressedKv, EvictPolicyKind, SpillArena, SpillArenaStats, SpilledKv};
use crate::runtime::kvpool::{BlockPool, KvPoolConfig, KvPoolStats, PagedSeq, SeqKv};
use crate::runtime::Engine;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Whether decode reuses the KV cache (Table 7's "Use KV Cache" axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GenerationMode {
    /// Prefill once, then one decode step per token (cache reused).
    KvCache,
    /// Re-run the full prefill for every generated token — the paper's
    /// no-cache row (and what 2:4 sparse models are forced into when the
    /// sparse kernel can't run the cache ops).
    NoKvCache,
}

/// One lane's contribution to a shared decode iteration.
pub struct StepInput<'a> {
    /// Lane index (stable for the session's lifetime).
    pub lane: usize,
    /// The most recently sampled token (to be fed this step).
    pub token: usize,
    /// Full sequence so far: prompt + generated, `token` last. No-KV
    /// backends re-prefill this; KV backends only consume `token`.
    pub seq: &'a [usize],
}

/// Per-lane outcome of one shared decode iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum StepResult {
    /// The lane advanced one token; its logits row.
    Logits(Vec<f32>),
    /// The lane failed (KV bounds, pool exhaustion) at `pos`; only this
    /// session should be failed — the other lanes' results are valid.
    Fault { pos: usize, msg: String },
}

/// Block-aware admission verdict (paged backends; DESIGN.md §8).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmitVerdict {
    /// Enough free blocks: admit now.
    Admit,
    /// Temporarily short on blocks: leave the request queued.
    Defer,
    /// The request can never fit this pool: reject it.
    Reject(String),
}

/// Paged-KV sizing for [`NativeBackend`].
#[derive(Clone, Debug)]
pub struct PagedKvParams {
    /// Token rows per block.
    pub block_tokens: usize,
    /// Physical blocks in the pool.
    pub num_blocks: usize,
    /// Admission low-watermark: keep this many blocks free per active
    /// session when gating new admissions (decode headroom).
    pub watermark_per_active: usize,
}

impl PagedKvParams {
    /// A pool holding the bytes of the old contiguous `lanes × max_seq`
    /// cache, rounded up to whole blocks per lane (exact when
    /// `block_tokens` divides `max_seq` — true for the default 16 and
    /// the tiny-model family's 128) — the equal-memory comparison
    /// point. Delegates to [`KvPoolConfig::matching_contiguous`] (the
    /// block count is independent of layers/dim) so the sizing formula
    /// lives in one place.
    pub fn matching_contiguous(lanes: usize, max_seq: usize) -> Self {
        let cfg = KvPoolConfig::matching_contiguous(1, 1, lanes, max_seq);
        Self {
            block_tokens: cfg.block_tokens,
            num_blocks: cfg.num_blocks,
            watermark_per_active: 1,
        }
    }
}

/// KV lifecycle configuration (DESIGN.md §10): idle-block eviction
/// policy, spill preemption, and cold-block compression. Applied to a
/// paged [`NativeBackend`] via [`NativeBackend::with_kvlife`]; a no-op
/// for other layouts.
#[derive(Clone, Copy, Debug)]
pub struct KvLifeConfig {
    /// Which idle block to sacrifice when the free list is empty
    /// (`pifa serve --kv-evict`).
    pub evict: EvictPolicyKind,
    /// Allow the scheduler to preempt low-priority sessions into the
    /// host spill arena (`--kv-spill`).
    pub spill: bool,
    /// PIFA-factorize cold spilled K/V blocks (`--kv-compress`;
    /// implies lossy resume above the matrix's true rank).
    pub compress: bool,
    /// Compression rank as a fraction of `min(len, dim)`.
    pub rank_frac: f64,
}

impl Default for KvLifeConfig {
    fn default() -> Self {
        Self { evict: EvictPolicyKind::Fifo, spill: false, compress: false, rank_frac: 0.5 }
    }
}

/// Per-lane generation state owned by a backend. `prefill` claims a
/// lane, `step` advances any subset of claimed lanes by one token, and
/// `release` frees a lane for reuse (cancel / finish).
pub trait DecodeBackend {
    /// Number of concurrent lanes this backend can hold.
    fn lanes(&self) -> usize;
    /// Maximum total sequence length (prompt + generated) a lane holds.
    fn max_seq(&self) -> usize;
    /// Maximum prompt length accepted by `prefill`.
    fn max_prompt(&self) -> usize {
        self.max_seq()
    }
    /// Run the prompt through the model on `lane`; returns the logits row
    /// for the final prompt position.
    fn prefill(&mut self, lane: usize, prompt: &[usize]) -> Result<Vec<f32>>;
    /// Advance an in-flight prefill on `lane` by up to `budget` prompt
    /// positions (`0` = unbounded), given that `done` positions are
    /// already resident (`done == 0` claims the lane). Returns the new
    /// resident count — which may exceed `done + budget` when the
    /// backend serves a prefix from cache — and, once the whole prompt
    /// is resident, the final position's logits row. An `Err` leaves the
    /// lane unclaimed (the backend cleans up its partial state).
    ///
    /// The default is the correct monolithic fallback for backends
    /// without incremental prefill (e.g. artifact-driven `PjrtBackend`,
    /// whose prefill executable consumes the whole prompt in one call):
    /// the first chunk runs the entire prompt regardless of budget.
    fn prefill_chunk(
        &mut self,
        lane: usize,
        prompt: &[usize],
        done: usize,
        _budget: usize,
    ) -> Result<(usize, Option<Vec<f32>>)> {
        debug_assert_eq!(done, 0, "monolithic fallback cannot resume mid-prefill");
        let logits = self.prefill(lane, prompt)?;
        Ok((prompt.len(), Some(logits)))
    }
    /// Advance the given lanes one token; returns one [`StepResult`] per
    /// input, in input order. `Err` means the engine state is unknown
    /// (every in-flight session fails); a per-lane [`StepResult::Fault`]
    /// fails only that lane's session.
    fn step(&mut self, inputs: &[StepInput<'_>]) -> Result<Vec<StepResult>>;
    /// Whether this backend implements the speculative verify/rollback
    /// pair (DESIGN.md §11). The scheduler only marks sessions
    /// speculative when this holds.
    fn supports_speculation(&self) -> bool {
        false
    }
    /// Speculative verify: feed `tokens` — the session's last committed
    /// token followed by its draft tokens — through `lane` in order,
    /// returning one [`StepResult`] per position fed. Stops at the first
    /// per-lane KV fault (appending that `Fault` last), so a caller can
    /// still accept the prefix that did score; positions past the fault
    /// are never computed. Must be arithmetically identical to feeding
    /// the same tokens through [`DecodeBackend::step`] one at a time —
    /// the bitwise contract `rust/tests/spec_differential.rs` pins.
    /// `Err` means the engine state is unknown, as with `step`.
    fn verify(&mut self, _lane: usize, _tokens: &[usize]) -> Result<Vec<StepResult>> {
        bail!("backend does not support speculative verify")
    }
    /// Roll `lane`'s KV state back to `len` cached positions, discarding
    /// rejected draft rows. A `len` at or past the current length is a
    /// no-op.
    fn rollback(&mut self, _lane: usize, _len: usize) -> Result<()> {
        bail!("backend does not support KV rollback")
    }
    /// Free a lane's state so a queued session can claim it.
    fn release(&mut self, lane: usize);
    /// Block-aware admission gate: can a session with this prompt length
    /// and token budget start now? Non-paged backends always admit.
    fn admit_check(&self, _prompt_len: usize, _max_new: usize) -> AdmitVerdict {
        AdmitVerdict::Admit
    }
    /// Paged-KV pool counters, when the backend has a pool.
    fn kv_stats(&self) -> Option<KvPoolStats> {
        None
    }
    /// Preempt: export `lane`'s KV state into the backend's host spill
    /// arena and free the lane, returning a resume ticket. `None` means
    /// the backend cannot spill (non-paged layouts, spill disabled) —
    /// the caller must then `release` the lane itself and resume by
    /// re-prefilling the session's sequence.
    fn spill(&mut self, _lane: usize) -> Option<u64> {
        None
    }
    /// Re-import a spilled ticket onto a free `lane`. `Ok(false)` means
    /// the pool is too tight right now — the ticket stays parked, retry
    /// later. `Ok(true)` consumes the ticket and claims the lane.
    fn resume(&mut self, _lane: usize, ticket: u64) -> Result<bool> {
        bail!("backend cannot resume spilled ticket {ticket}")
    }
    /// Discard a spilled ticket (the session reached a terminal state
    /// while spilled). No-op for unknown tickets.
    fn drop_spilled(&mut self, _ticket: u64) {}
    /// Spill-arena counters, when the backend has one and spill is on.
    fn spill_stats(&self) -> Option<SpillArenaStats> {
        None
    }
    /// Diagnostic label.
    fn name(&self) -> &'static str {
        "backend"
    }
}

/// KV storage behind [`NativeBackend`].
enum NativeKv {
    /// One dense [`KvCache`] per lane (the pre-paging reference layout).
    Contiguous(Vec<Option<KvCache>>),
    /// Shared block pool + per-lane block tables (DESIGN.md §8), plus
    /// the lifecycle layer above them (§10): the host spill arena and
    /// its configuration.
    Paged {
        pool: BlockPool,
        seqs: Vec<Option<SeqKv>>,
        params: PagedKvParams,
        arena: SpillArena,
        life: KvLifeConfig,
    },
}

/// Pure-Rust backend over a [`Transformer`].
pub struct NativeBackend {
    model: Transformer,
    mode: GenerationMode,
    kv: NativeKv,
}

/// Per-lane step job (token + owned cache) handed to a pool job.
type LaneJob = Mutex<Option<(usize, KvCache)>>;
/// Per-lane step result (logits + the cache handed back).
type LaneDone = Mutex<Option<(Mat<f32>, KvCache)>>;
/// Per-lane paged step job (token + raw-slab lane view).
type PagedJob = Mutex<Option<(usize, LaneView)>>;
/// Per-lane paged step outcome.
type PagedDone = Mutex<Option<Result<Mat<f32>, KvStoreFull>>>;

impl NativeBackend {
    /// Default construction: paged KV sized to the same memory as a
    /// contiguous `lanes × max_seq` cache, which typically exposes *more*
    /// lanes than `lanes` (short sessions don't reserve `max_seq` rows).
    /// No-KV mode has no cache to page and keeps plain lane slots.
    pub fn new(model: Transformer, mode: GenerationMode, lanes: usize) -> Self {
        match mode {
            GenerationMode::KvCache => {
                let params = PagedKvParams::matching_contiguous(lanes, model.cfg.max_seq);
                Self::paged(model, mode, params)
            }
            GenerationMode::NoKvCache => Self::contiguous(model, mode, lanes),
        }
    }

    /// The contiguous per-lane layout (fixed lane count) — the reference
    /// the paged path is differentially tested against.
    pub fn contiguous(model: Transformer, mode: GenerationMode, lanes: usize) -> Self {
        // Spawn the kernel pool now so the first decode token does not
        // pay the worker start-up cost.
        pool::prewarm();
        Self {
            model,
            mode,
            kv: NativeKv::Contiguous((0..lanes.max(1)).map(|_| None).collect()),
        }
    }

    /// Paged KV with explicit pool sizing. The lane cap is the block
    /// count (every session needs at least one block); admission is
    /// gated by the free-block watermark, not the lane count.
    pub fn paged(model: Transformer, mode: GenerationMode, params: PagedKvParams) -> Self {
        pool::prewarm();
        let cfg = KvPoolConfig {
            layers: model.cfg.n_layers,
            dim: model.cfg.dim,
            block_tokens: params.block_tokens.max(1),
            num_blocks: params.num_blocks.max(1),
        };
        let lanes = cfg.num_blocks;
        Self {
            model,
            mode,
            kv: NativeKv::Paged {
                pool: BlockPool::new(cfg),
                seqs: (0..lanes).map(|_| None).collect(),
                params,
                arena: SpillArena::new(),
                life: KvLifeConfig::default(),
            },
        }
    }

    /// Configure the KV lifecycle layer (DESIGN.md §10). A no-op for
    /// non-paged layouts, which have no pool to evict from or spill.
    pub fn with_kvlife(mut self, life: KvLifeConfig) -> Self {
        if let NativeKv::Paged { pool, life: slot, .. } = &mut self.kv {
            pool.set_policy(life.evict);
            *slot = life;
        }
        self
    }

    fn lane_count(&self) -> usize {
        match &self.kv {
            NativeKv::Contiguous(c) => c.len(),
            NativeKv::Paged { seqs, .. } => seqs.len(),
        }
    }

    fn lane_claimed(&self, lane: usize) -> bool {
        match &self.kv {
            NativeKv::Contiguous(c) => c.get(lane).is_some_and(|s| s.is_some()),
            NativeKv::Paged { seqs, .. } => seqs.get(lane).is_some_and(|s| s.is_some()),
        }
    }
}

/// Contiguous KV iteration: per-lane capacity faults resolve locally,
/// healthy lanes fan out across the kernel pool (the per-lane GEMVs
/// inside run inline — nested pool calls do not re-enter).
fn step_contiguous(
    model: &Transformer,
    caches: &mut [Option<KvCache>],
    inputs: &[StepInput<'_>],
) -> Result<Vec<StepResult>> {
    let mut out: Vec<Option<StepResult>> = (0..inputs.len()).map(|_| None).collect();
    let mut live: Vec<usize> = Vec::new();
    for (i, inp) in inputs.iter().enumerate() {
        let cache = caches[inp.lane].as_ref().expect("validated by caller");
        if cache.len >= cache.capacity {
            out[i] = Some(StepResult::Fault {
                pos: cache.len,
                msg: format!("KV cache full at {}/{}", cache.len, cache.capacity),
            });
        } else {
            live.push(i);
        }
    }
    // Move each live lane's cache into its job slot; jobs own it for the
    // duration of the scope and hand it back with the logits.
    let jobs: Vec<LaneJob> = live
        .iter()
        .map(|&i| Mutex::new(Some((inputs[i].token, caches[inputs[i].lane].take().unwrap()))))
        .collect();
    let done: Vec<LaneDone> = live.iter().map(|_| Mutex::new(None)).collect();
    pool::scope_run(jobs.len(), |j| {
        let (token, mut cache) = jobs[j].lock().unwrap().take().unwrap();
        let logits = model.decode_step(token, &mut cache);
        *done[j].lock().unwrap() = Some((logits, cache));
    });
    for (&i, slot) in live.iter().zip(done) {
        let (logits, cache) =
            slot.into_inner().unwrap().context("lane step produced no result")?;
        caches[inputs[i].lane] = Some(cache);
        out[i] = Some(StepResult::Logits(logits.row(0).to_vec()));
    }
    Ok(out.into_iter().map(|o| o.expect("every input resolved")).collect())
}

/// Paged KV iteration. Serial phase: block reservation per lane
/// (`BlockPool::append` — alloc / copy-on-write / sharing-index update);
/// a reservation failure (pool exhausted mid-decode) faults only that
/// lane. Parallel phase: disjoint-write [`LaneView`]s advance the
/// healthy lanes across the kernel pool (soundness argument in
/// `runtime::kernels::gather`).
fn step_paged(
    model: &Transformer,
    blkpool: &mut BlockPool,
    seqs: &mut [Option<SeqKv>],
    inputs: &[StepInput<'_>],
    max_seq: usize,
) -> Result<Vec<StepResult>> {
    let mut out: Vec<Option<StepResult>> = (0..inputs.len()).map(|_| None).collect();
    let mut live: Vec<usize> = Vec::new();
    for (i, inp) in inputs.iter().enumerate() {
        let seq = seqs[inp.lane].as_mut().expect("validated by caller");
        if seq.len() >= max_seq {
            out[i] = Some(StepResult::Fault {
                pos: seq.len(),
                msg: format!("KV sequence capacity {max_seq} reached"),
            });
            continue;
        }
        match blkpool.append(seq, inp.token) {
            Ok(()) => live.push(i),
            Err(e) => {
                out[i] = Some(StepResult::Fault { pos: e.pos(), msg: e.to_string() });
            }
        }
    }
    // One pool borrow builds every view, so all raw slab pointers share
    // a provenance (see `gather::lane_views`).
    let live_seqs: Vec<&SeqKv> = live
        .iter()
        .map(|&i| seqs[inputs[i].lane].as_ref().expect("validated by caller"))
        .collect();
    let jobs: Vec<PagedJob> = gather::lane_views(blkpool, &live_seqs)
        .into_iter()
        .zip(live.iter())
        .map(|(view, &i)| Mutex::new(Some((inputs[i].token, view))))
        .collect();
    drop(live_seqs);
    let done: Vec<PagedDone> = live.iter().map(|_| Mutex::new(None)).collect();
    pool::scope_run(jobs.len(), |j| {
        let (token, mut view) = jobs[j].lock().unwrap().take().unwrap();
        *done[j].lock().unwrap() = Some(model.decode_step_kv(token, &mut view));
    });
    for (&i, slot) in live.iter().zip(done) {
        let res = slot.into_inner().unwrap().context("lane step produced no result")?;
        out[i] = Some(match res {
            Ok(logits) => StepResult::Logits(logits.row(0).to_vec()),
            Err(e) => StepResult::Fault { pos: e.pos, msg: e.detail },
        });
    }
    Ok(out.into_iter().map(|o| o.expect("every input resolved")).collect())
}

impl DecodeBackend for NativeBackend {
    fn lanes(&self) -> usize {
        self.lane_count()
    }

    fn max_seq(&self) -> usize {
        self.model.cfg.max_seq
    }

    fn prefill(&mut self, lane: usize, prompt: &[usize]) -> Result<Vec<f32>> {
        // One unbounded chunk: the monolithic path and the chunked path
        // are the *same* token loop, so `--prefill-chunk` can never
        // change a logit (the bitwise contract kv_differential pins).
        let (_, logits) = self.prefill_chunk(lane, prompt, 0, 0)?;
        logits.context("unbudgeted prefill chunk must complete the prompt")
    }

    fn prefill_chunk(
        &mut self,
        lane: usize,
        prompt: &[usize],
        done: usize,
        budget: usize,
    ) -> Result<(usize, Option<Vec<f32>>)> {
        if lane >= self.lane_count() {
            bail!("lane {lane} out of range ({} lanes)", self.lane_count());
        }
        if prompt.is_empty() || prompt.len() > self.max_prompt() {
            bail!("prompt length {} not in 1..={}", prompt.len(), self.max_prompt());
        }
        if done >= prompt.len() {
            bail!("prefill chunk past the prompt end ({done} >= {})", prompt.len());
        }
        let max_seq = self.model.cfg.max_seq;
        let model = &self.model;
        match self.mode {
            GenerationMode::KvCache => match &mut self.kv {
                NativeKv::Contiguous(caches) => {
                    if done == 0 {
                        caches[lane] = Some(KvCache::new(&model.cfg));
                    }
                    let Some(cache) = caches[lane].as_mut() else {
                        bail!("lane {lane} has no in-flight prefill to continue");
                    };
                    if cache.len != done {
                        let have = cache.len;
                        caches[lane] = None;
                        bail!("lane {lane} prefill cursor mismatch: {have} cached vs {done} fed");
                    }
                    let end =
                        if budget == 0 { prompt.len() } else { (done + budget).min(prompt.len()) };
                    let mut logits = None;
                    for &t in &prompt[done..end] {
                        logits = Some(model.decode_step(t, cache));
                    }
                    if end == prompt.len() {
                        let l = logits.expect("chunk is non-empty").row(0).to_vec();
                        Ok((end, Some(l)))
                    } else {
                        Ok((end, None))
                    }
                }
                NativeKv::Paged { pool: blkpool, seqs, .. } => {
                    if done == 0 {
                        // Defensive: a stale table on this lane is released
                        // before the new session claims it.
                        if let Some(old) = seqs[lane].take() {
                            blkpool.release(old);
                        }
                        // Attach the longest resident shared prefix; only
                        // the tail (always including the final position,
                        // whose logits we need) is recomputed. The jump
                        // is free, so it does not count against `budget`.
                        let (seq, _reused) = blkpool.begin(prompt);
                        seqs[lane] = Some(seq);
                    }
                    let Some(start) = seqs[lane].as_ref().map(|s| s.len()) else {
                        bail!("lane {lane} has no in-flight prefill to continue");
                    };
                    if done > 0 && start != done {
                        let seq = seqs[lane].take().expect("length just read");
                        blkpool.release(seq);
                        bail!("lane {lane} prefill cursor mismatch: {start} resident vs {done} fed");
                    }
                    let end =
                        if budget == 0 { prompt.len() } else { (start + budget).min(prompt.len()) };
                    let mut seq = seqs[lane].take().expect("length just read");
                    let mut logits: Option<Mat<f32>> = None;
                    for &t in &prompt[start..end] {
                        let mut store =
                            PagedSeq { pool: &mut *blkpool, seq: &mut seq, cap: max_seq };
                        match model.decode_step_kv(t, &mut store) {
                            Ok(l) => logits = Some(l),
                            Err(e) => {
                                blkpool.release(seq);
                                bail!("paged prefill failed: {e}");
                            }
                        }
                    }
                    seqs[lane] = Some(seq);
                    if end == prompt.len() {
                        // Prefix reuse is capped at len − 1, so the final
                        // position was recomputed in some chunk's loop —
                        // this one, because earlier chunks end before it.
                        let l = logits
                            .expect("final position recomputed")
                            .row(0)
                            .to_vec();
                        Ok((end, Some(l)))
                    } else {
                        Ok((end, None))
                    }
                }
            },
            GenerationMode::NoKvCache => {
                // No cache to grow incrementally: one full forward serves
                // the whole prompt regardless of budget (a single maximal
                // chunk; re-prefill mode recomputes it every step anyway).
                let logits = model.forward(prompt, None);
                Ok((prompt.len(), Some(logits.row(prompt.len() - 1).to_vec())))
            }
        }
    }

    fn step(&mut self, inputs: &[StepInput<'_>]) -> Result<Vec<StepResult>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        match self.mode {
            GenerationMode::KvCache => {
                // Engine-wide validation (programming errors, not session
                // faults): lane range, claimed state, duplicates.
                let lanes_n = self.lane_count();
                let mut seen = vec![false; lanes_n];
                for inp in inputs {
                    if inp.lane >= lanes_n {
                        bail!("lane {} out of range ({lanes_n} lanes)", inp.lane);
                    }
                    if seen[inp.lane] {
                        bail!("lane {} appears twice in one iteration", inp.lane);
                    }
                    seen[inp.lane] = true;
                    if !self.lane_claimed(inp.lane) {
                        bail!("lane {} has no prefilled cache", inp.lane);
                    }
                }
                let max_seq = self.model.cfg.max_seq;
                let model = &self.model;
                match &mut self.kv {
                    NativeKv::Contiguous(caches) => step_contiguous(model, caches, inputs),
                    NativeKv::Paged { pool: blkpool, seqs, .. } => {
                        step_paged(model, blkpool, seqs, inputs, max_seq)
                    }
                }
            }
            GenerationMode::NoKvCache => {
                for inp in inputs {
                    if inp.seq.is_empty() || inp.seq.len() > self.model.cfg.max_seq {
                        bail!("sequence length {} exceeds max_seq", inp.seq.len());
                    }
                }
                // Full re-prefill every step — the no-cache cost. Each
                // lane's forward is prefill-sized, so its inner GEMMs can
                // use the whole pool; fanning lanes out would serialize
                // them (nested pool calls run inline). Only go
                // lane-parallel when there are enough lanes to cover the
                // machine on their own.
                let done: Vec<Mutex<Option<Mat<f32>>>> =
                    inputs.iter().map(|_| Mutex::new(None)).collect();
                let model = &self.model;
                if inputs.len() >= pool::max_parallelism() {
                    pool::scope_run(inputs.len(), |i| {
                        *done[i].lock().unwrap() = Some(model.forward(inputs[i].seq, None));
                    });
                } else {
                    for (inp, slot) in inputs.iter().zip(done.iter()) {
                        *slot.lock().unwrap() = Some(model.forward(inp.seq, None));
                    }
                }
                inputs
                    .iter()
                    .zip(done)
                    .map(|(inp, slot)| {
                        let logits =
                            slot.into_inner().unwrap().context("lane step produced no result")?;
                        Ok(StepResult::Logits(logits.row(inp.seq.len() - 1).to_vec()))
                    })
                    .collect()
            }
        }
    }

    fn supports_speculation(&self) -> bool {
        // NoKvCache has nothing to roll back (each step re-prefills).
        self.mode == GenerationMode::KvCache
    }

    fn verify(&mut self, lane: usize, tokens: &[usize]) -> Result<Vec<StepResult>> {
        if self.mode != GenerationMode::KvCache {
            bail!("speculative verify requires the KV cache");
        }
        if lane >= self.lane_count() || !self.lane_claimed(lane) {
            bail!("verify on unclaimed lane {lane}");
        }
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        let max_seq = self.model.cfg.max_seq;
        let model = &self.model;
        // Both layouts run the span through the same sequential
        // `decode_span_kv` the plain per-token paths use, so the logits
        // are bitwise-identical to k+1 ordinary steps.
        let (rows, fault) = match &mut self.kv {
            NativeKv::Contiguous(caches) => {
                let cache = caches[lane].as_mut().expect("claimed lane has a cache");
                model.decode_span_kv(tokens, cache)
            }
            NativeKv::Paged { pool: blkpool, seqs, .. } => {
                let seq = seqs[lane].as_mut().expect("claimed lane has a table");
                let mut store = PagedSeq { pool: &mut *blkpool, seq: &mut *seq, cap: max_seq };
                model.decode_span_kv(tokens, &mut store)
            }
        };
        let mut out: Vec<StepResult> = rows
            .into_iter()
            .map(|l| StepResult::Logits(l.row(0).to_vec()))
            .collect();
        if let Some(e) = fault {
            out.push(StepResult::Fault { pos: e.pos, msg: e.detail });
        }
        Ok(out)
    }

    fn rollback(&mut self, lane: usize, len: usize) -> Result<()> {
        match &mut self.kv {
            NativeKv::Contiguous(caches) => {
                let cache = caches
                    .get_mut(lane)
                    .and_then(|c| c.as_mut())
                    .with_context(|| format!("rollback on unclaimed lane {lane}"))?;
                cache.len = cache.len.min(len);
                Ok(())
            }
            NativeKv::Paged { pool: blkpool, seqs, .. } => {
                let seq = seqs
                    .get_mut(lane)
                    .and_then(|s| s.as_mut())
                    .with_context(|| format!("rollback on unclaimed lane {lane}"))?;
                blkpool.truncate(seq, len);
                Ok(())
            }
        }
    }

    fn release(&mut self, lane: usize) {
        match &mut self.kv {
            NativeKv::Contiguous(caches) => {
                if let Some(c) = caches.get_mut(lane) {
                    *c = None;
                }
            }
            NativeKv::Paged { pool: blkpool, seqs, .. } => {
                if let Some(seq) = seqs.get_mut(lane).and_then(|s| s.take()) {
                    blkpool.release(seq);
                }
            }
        }
    }

    fn admit_check(&self, prompt_len: usize, max_new: usize) -> AdmitVerdict {
        if self.mode == GenerationMode::NoKvCache {
            return AdmitVerdict::Admit;
        }
        let NativeKv::Paged { pool: blkpool, seqs, params, .. } = &self.kv else {
            return AdmitVerdict::Admit;
        };
        let max_seq = self.model.cfg.max_seq;
        let worst = (prompt_len + max_new).clamp(1, max_seq);
        if blkpool.blocks_for(worst) > blkpool.config().num_blocks {
            return AdmitVerdict::Reject(format!(
                "session needs {} blocks at its longest, pool holds {}",
                blkpool.blocks_for(worst),
                blkpool.config().num_blocks
            ));
        }
        // Admit while the prompt (plus one decode row) fits and the
        // watermark leaves headroom for in-flight sessions to grow.
        let needed = blkpool.blocks_for((prompt_len + 1).min(max_seq));
        let active = seqs.iter().filter(|s| s.is_some()).count();
        if blkpool.allocatable_blocks() < needed + params.watermark_per_active * active {
            AdmitVerdict::Defer
        } else {
            AdmitVerdict::Admit
        }
    }

    fn kv_stats(&self) -> Option<KvPoolStats> {
        match (&self.kv, self.mode) {
            (NativeKv::Paged { pool: blkpool, .. }, GenerationMode::KvCache) => {
                Some(blkpool.stats())
            }
            _ => None,
        }
    }

    fn spill(&mut self, lane: usize) -> Option<u64> {
        if self.mode != GenerationMode::KvCache {
            return None;
        }
        let NativeKv::Paged { pool: blkpool, seqs, arena, life, .. } = &mut self.kv else {
            return None;
        };
        if !life.spill {
            return None;
        }
        let seq = seqs.get_mut(lane)?.take()?;
        let tokens = blkpool.tokens_of(&seq);
        let (k, v) = blkpool.export_kv(&seq);
        blkpool.release(seq);
        let (n, d) = (tokens.len(), blkpool.config().dim);
        let per = n * d;
        let mut ck = Vec::with_capacity(blkpool.config().layers);
        let mut cv = Vec::with_capacity(blkpool.config().layers);
        for layer in 0..blkpool.config().layers {
            let ks = &k[layer * per..(layer + 1) * per];
            let vs = &v[layer * per..(layer + 1) * per];
            if life.compress {
                ck.push(CompressedKv::compress(n, d, ks, life.rank_frac));
                cv.push(CompressedKv::compress(n, d, vs, life.rank_frac));
            } else {
                ck.push(CompressedKv::raw(n, d, ks.to_vec()));
                cv.push(CompressedKv::raw(n, d, vs.to_vec()));
            }
        }
        Some(arena.insert(SpilledKv { tokens, k: ck, v: cv }))
    }

    fn resume(&mut self, lane: usize, ticket: u64) -> Result<bool> {
        let max_seq = self.model.cfg.max_seq;
        let NativeKv::Paged { pool: blkpool, seqs, arena, .. } = &mut self.kv else {
            bail!("contiguous backend cannot resume spilled ticket {ticket}");
        };
        if lane >= seqs.len() {
            bail!("lane {lane} out of range ({} lanes)", seqs.len());
        }
        if seqs[lane].is_some() {
            bail!("lane {lane} already claimed");
        }
        let Some(entry) = arena.get(ticket) else {
            bail!("unknown spill ticket {ticket}");
        };
        // Worst-case capacity pre-check (resident-prefix re-attach only
        // needs fewer): refuse rather than fail an import mid-way, and
        // keep room for the next decode row.
        let need = blkpool.blocks_for((entry.tokens.len() + 1).min(max_seq));
        if blkpool.allocatable_blocks() < need {
            return Ok(false);
        }
        let entry = arena.take(ticket).expect("ticket checked resident above");
        let (k, v) = entry.materialize();
        match blkpool.import_kv(&entry.tokens, &k, &v) {
            Ok(seq) => {
                seqs[lane] = Some(seq);
                Ok(true)
            }
            Err(e) => bail!("resume import failed despite capacity pre-check: {e}"),
        }
    }

    fn drop_spilled(&mut self, ticket: u64) {
        if let NativeKv::Paged { arena, .. } = &mut self.kv {
            arena.drop_ticket(ticket);
        }
    }

    fn spill_stats(&self) -> Option<SpillArenaStats> {
        match (&self.kv, self.mode) {
            (NativeKv::Paged { arena, life, .. }, GenerationMode::KvCache) if life.spill => {
                Some(arena.stats())
            }
            _ => None,
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// PJRT backend: lanes are batch rows of the static-batch decode
/// artifact; per-lane KV lives in the paged [`LaneKv`], which keeps one
/// block table per lane (shared prompt prefixes map the same physical
/// blocks) and gathers the merged `(L,B,S,d)` literal only at decode
/// call time. Lanes at the same sequence position share one decode call.
pub struct PjrtBackend {
    pjrt: Engine,
    runner: ModelRunner,
    mode: GenerationMode,
    kv: LaneKv,
}

impl PjrtBackend {
    pub fn new(pjrt: Engine, runner: ModelRunner, mode: GenerationMode) -> Self {
        let kv = runner.lane_kv();
        Self { pjrt, runner, mode, kv }
    }
}

impl DecodeBackend for PjrtBackend {
    fn lanes(&self) -> usize {
        self.runner.batch.max(1)
    }

    fn max_seq(&self) -> usize {
        match self.mode {
            GenerationMode::KvCache => self.runner.max_seq,
            // Without the cache every step re-prefills the whole
            // sequence, so the prefill artifact's window is the cap.
            GenerationMode::NoKvCache => self.runner.prefill_seq,
        }
    }

    fn max_prompt(&self) -> usize {
        self.runner.prefill_seq
    }

    fn prefill(&mut self, lane: usize, prompt: &[usize]) -> Result<Vec<f32>> {
        if lane >= self.lanes() {
            bail!("lane {lane} out of range ({} lanes)", self.lanes());
        }
        let (logits, kvs) = self.runner.prefill(&mut self.pjrt, prompt)?;
        if self.mode == GenerationMode::KvCache {
            // Borrowed views: no full-cache copies on the claim path.
            // Shared prompt prefixes dedupe into already-resident blocks.
            let k = literal_f32_view(&kvs.k)?;
            let v = literal_f32_view(&kvs.v)?;
            self.kv
                .write_lane(lane, prompt, k, v, prompt.len())
                .map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        Ok(self.runner.logits_at(&logits, prompt.len() - 1))
    }

    fn step(&mut self, inputs: &[StepInput<'_>]) -> Result<Vec<StepResult>> {
        match self.mode {
            GenerationMode::NoKvCache => {
                let mut out = Vec::with_capacity(inputs.len());
                for inp in inputs {
                    let (logits, _) = self.runner.prefill(&mut self.pjrt, inp.seq)?;
                    out.push(StepResult::Logits(
                        self.runner.logits_at(&logits, inp.seq.len() - 1),
                    ));
                }
                Ok(out)
            }
            GenerationMode::KvCache => {
                // Group lanes by shared position: the decode artifact
                // takes one scalar `pos`, so only same-position lanes
                // can share a call. Mixed-length traffic still shares
                // whenever prompts align or converge. A lane at its KV
                // capacity is a per-lane fault, not an engine failure.
                let mut out: Vec<Option<StepResult>> =
                    (0..inputs.len()).map(|_| None).collect();
                let mut by_pos: BTreeMap<usize, Vec<(usize, usize, usize)>> = BTreeMap::new();
                for (i, inp) in inputs.iter().enumerate() {
                    if inp.lane >= self.lanes() {
                        bail!("lane {} out of range", inp.lane);
                    }
                    let pos = self.kv.pos(inp.lane);
                    if pos == 0 {
                        bail!("lane {} stepped without prefill", inp.lane);
                    }
                    if pos >= self.runner.max_seq {
                        out[i] = Some(StepResult::Fault {
                            pos,
                            msg: format!("KV cache full at pos {pos}"),
                        });
                        continue;
                    }
                    by_pos.entry(pos).or_default().push((i, inp.lane, inp.token));
                }
                for (pos, group) in by_pos {
                    let mut tokens = vec![0usize; self.runner.batch];
                    for &(_, lane, token) in &group {
                        tokens[lane] = token;
                    }
                    // Each group pays one merged gather + decode call.
                    // With the vendored host-side xla stub this is a
                    // plain memcpy; a real device runtime would keep the
                    // cache resident instead.
                    let (k_lit, v_lit) = self.kv.merged_literals()?;
                    let state = KvState { k: k_lit, v: v_lit, pos };
                    let (rows, new_state) =
                        self.runner.decode_step(&mut self.pjrt, state, &tokens)?;
                    let kview = literal_f32_view(&new_state.k)?;
                    let vview = literal_f32_view(&new_state.v)?;
                    for &(i, lane, token) in &group {
                        let absorbed = self.kv.absorb_lane(lane, token, kview, vview, pos);
                        out[i] = Some(match absorbed {
                            Ok(()) => StepResult::Logits(rows[lane].clone()),
                            Err(e) => StepResult::Fault { pos: e.pos, msg: e.msg },
                        });
                    }
                }
                Ok(out.into_iter().map(|o| o.expect("every input resolved")).collect())
            }
        }
    }

    fn release(&mut self, lane: usize) {
        self.kv.reset_lane(lane);
    }

    fn admit_check(&self, prompt_len: usize, _max_new: usize) -> AdmitVerdict {
        if self.mode != GenerationMode::KvCache {
            return AdmitVerdict::Admit;
        }
        // Watermark: one spare block per active lane for decode growth.
        let needed = self.kv.blocks_for((prompt_len + 1).min(self.runner.max_seq));
        if self.kv.allocatable_blocks() < needed + self.kv.active_lanes() {
            AdmitVerdict::Defer
        } else {
            AdmitVerdict::Admit
        }
    }

    fn kv_stats(&self) -> Option<KvPoolStats> {
        match self.mode {
            GenerationMode::KvCache => Some(self.kv.stats()),
            GenerationMode::NoKvCache => None,
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::model::config::ModelConfig;
    use crate::runtime::exec::argmax;

    fn tiny_model(seed: u64) -> Transformer {
        let cfg = ModelConfig::tiny_s();
        let mut rng = Rng::new(seed);
        Transformer::new_random(&cfg, &mut rng)
    }

    /// A much smaller transformer for pool-edge-case tests.
    fn micro_model(seed: u64, max_seq: usize) -> Transformer {
        let cfg = ModelConfig {
            name: "micro".into(),
            vocab: 32,
            dim: 16,
            n_layers: 2,
            n_heads: 2,
            ffn_hidden: 24,
            max_seq,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        };
        let mut rng = Rng::new(seed);
        Transformer::new_random(&cfg, &mut rng)
    }

    fn logits_of(rows: &[StepResult], i: usize) -> &[f32] {
        match &rows[i] {
            StepResult::Logits(row) => row,
            StepResult::Fault { pos, msg } => {
                panic!("unexpected lane fault at pos {pos}: {msg}")
            }
        }
    }

    /// Greedy-generate through a backend exactly as the scheduler does:
    /// prefill emits token 0, each step emits one more.
    fn backend_greedy(
        backend: &mut dyn DecodeBackend,
        lane: usize,
        prompt: &[usize],
        max_new: usize,
    ) -> Vec<usize> {
        let logits = backend.prefill(lane, prompt).unwrap();
        let mut seq = prompt.to_vec();
        seq.push(argmax(&logits));
        while seq.len() - prompt.len() < max_new {
            let last = *seq.last().unwrap();
            let rows = backend
                .step(&[StepInput { lane, token: last, seq: &seq }])
                .unwrap();
            seq.push(argmax(logits_of(&rows, 0)));
        }
        backend.release(lane);
        seq[prompt.len()..].to_vec()
    }

    #[test]
    fn native_kv_backend_matches_model_generate() {
        let model = tiny_model(411);
        let prompt = vec![3usize, 11, 7, 2];
        let want = model.generate(&prompt, 6);
        let mut be = NativeBackend::new(model, GenerationMode::KvCache, 2);
        assert_eq!(backend_greedy(&mut be, 1, &prompt, 6), want);
    }

    #[test]
    fn native_contiguous_matches_model_generate() {
        let model = tiny_model(416);
        let prompt = vec![3usize, 11, 7, 2];
        let want = model.generate(&prompt, 6);
        let mut be = NativeBackend::contiguous(model, GenerationMode::KvCache, 2);
        assert_eq!(backend_greedy(&mut be, 1, &prompt, 6), want);
    }

    /// Chunked prefill is the monolithic token loop split across calls:
    /// for every budget (including 1 and past-the-prompt), the final
    /// logits row and the subsequent greedy decode stream must be
    /// bitwise-identical to the one-shot `prefill`, in both KV layouts.
    #[test]
    fn prefill_chunk_matches_monolithic_bitwise() {
        let model = micro_model(423, 64);
        let prompt = vec![3usize, 9, 1, 4, 7, 2, 5];
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        for contiguous in [false, true] {
            let make = |m: &Transformer| {
                if contiguous {
                    NativeBackend::contiguous(m.clone(), GenerationMode::KvCache, 2)
                } else {
                    NativeBackend::new(m.clone(), GenerationMode::KvCache, 2)
                }
            };
            let mut mono = make(&model);
            let want_logits = mono.prefill(0, &prompt).unwrap();
            mono.release(0);
            let want_gen = backend_greedy(&mut mono, 0, &prompt, 5);
            for budget in [1usize, 3, prompt.len(), prompt.len() + 9] {
                let mut be = make(&model);
                let mut done = 0usize;
                let mut chunks = 0usize;
                let logits = loop {
                    let (d, l) = be.prefill_chunk(0, &prompt, done, budget).unwrap();
                    assert!(d > done, "every chunk must make progress");
                    done = d;
                    chunks += 1;
                    if let Some(l) = l {
                        assert_eq!(done, prompt.len(), "logits only with the prompt resident");
                        break l;
                    }
                };
                assert_eq!(
                    chunks,
                    (prompt.len() + budget - 1) / budget,
                    "budget {budget} must take exactly ceil(len/budget) chunks on a cold pool"
                );
                assert_eq!(bits(&logits), bits(&want_logits), "budget {budget}");
                // The chunk-built KV state decodes identically too.
                let mut seq = prompt.clone();
                seq.push(argmax(&logits));
                while seq.len() - prompt.len() < want_gen.len() {
                    let last = *seq.last().unwrap();
                    let rows =
                        be.step(&[StepInput { lane: 0, token: last, seq: &seq }]).unwrap();
                    seq.push(argmax(logits_of(&rows, 0)));
                }
                be.release(0);
                assert_eq!(&seq[prompt.len()..], &want_gen[..], "budget {budget}");
            }
        }
        // Paged prefix reuse composes with chunking: a warm pool lets the
        // first chunk jump to len − 1 resident positions, so even budget
        // 1 completes a fully-cached prompt in one call.
        let mut be = NativeBackend::new(model.clone(), GenerationMode::KvCache, 2);
        let want_logits = be.prefill(0, &prompt).unwrap();
        let (done, l) = be.prefill_chunk(1, &prompt, 0, 1).unwrap();
        assert_eq!(done, prompt.len(), "cached prefix + 1-token budget covers the prompt");
        assert_eq!(bits(&l.expect("prompt resident")), bits(&want_logits));
    }

    /// Drive one lane through speculative verify spans (alternating
    /// deliberately-wrong and perfect drafts) with rollback after every
    /// round; the emitted greedy stream must be bitwise-identical to
    /// `Transformer::generate`, in both KV layouts.
    #[test]
    fn verify_rollback_reproduce_plain_greedy_bitwise() {
        let model = micro_model(419, 64);
        let vocab = model.cfg.vocab;
        let prompt = vec![3usize, 9, 1, 4];
        let want = model.generate(&prompt, 8);
        assert_eq!(want.len(), 8);
        for contiguous in [false, true] {
            let mut be = if contiguous {
                NativeBackend::contiguous(model.clone(), GenerationMode::KvCache, 2)
            } else {
                NativeBackend::new(model.clone(), GenerationMode::KvCache, 2)
            };
            assert!(be.supports_speculation());
            let logits = be.prefill(0, &prompt).unwrap();
            let mut seq = prompt.clone();
            seq.push(argmax(&logits));
            let k = 2usize;
            let mut round = 0usize;
            while seq.len() - prompt.len() < want.len() {
                let g = seq.len() - prompt.len();
                let perfect = round % 2 == 1;
                let mut tokens = vec![*seq.last().unwrap()];
                for j in 0..k {
                    let idx = (g + j).min(want.len() - 1);
                    // Perfect drafts are the true greedy continuations;
                    // garbage drafts are off-by-one, guaranteed rejected.
                    tokens.push(if perfect { want[idx] } else { (want[idx] + 1) % vocab });
                }
                let rows = be.verify(0, &tokens).unwrap();
                assert_eq!(rows.len(), k + 1, "no faults expected in this pool");
                let picks: Vec<usize> =
                    (0..rows.len()).map(|i| argmax(logits_of(&rows, i))).collect();
                let mut a = 0;
                while a < picks.len() - 1 && tokens[a + 1] == picks[a] {
                    a += 1;
                }
                if perfect && g + k <= want.len() {
                    assert_eq!(a, k, "perfect drafts must all be accepted");
                } else if !perfect {
                    assert_eq!(a, 0, "off-by-one drafts must all be rejected");
                }
                for &p in picks.iter().take(a + 1) {
                    if seq.len() - prompt.len() == want.len() {
                        break;
                    }
                    seq.push(p);
                }
                be.rollback(0, seq.len() - 1).unwrap();
                round += 1;
            }
            assert_eq!(
                &seq[prompt.len()..],
                &want[..],
                "speculative stream diverged (contiguous={contiguous})"
            );
            be.release(0);
        }
    }

    /// Pool exhaustion mid-verify returns the rows that did score plus a
    /// trailing fault; rollback then restores the lane so plain decode
    /// continues — the draft/verify path can never strand blocks.
    #[test]
    fn verify_exhaustion_yields_partial_rows_and_rolls_back() {
        let model = micro_model(420, 64);
        let mut be = NativeBackend::paged(
            model,
            GenerationMode::KvCache,
            PagedKvParams { block_tokens: 4, num_blocks: 2, watermark_per_active: 1 },
        );
        let prompt = vec![1usize, 2, 3, 4];
        let logits = be.prefill(0, &prompt).unwrap();
        let mut seq = prompt.clone();
        seq.push(argmax(&logits));
        // One spare block = 4 appendable positions; a 5-token span must
        // score 4 and fault on the fifth.
        let rows = be.verify(0, &[7, 8, 9, 10, 11]).unwrap();
        assert_eq!(rows.len(), 5);
        for row in rows.iter().take(4) {
            assert!(matches!(row, StepResult::Logits(_)));
        }
        match &rows[4] {
            StepResult::Fault { pos, .. } => assert_eq!(*pos, 8),
            other => panic!("expected a trailing fault, got {other:?}"),
        }
        be.rollback(0, prompt.len()).unwrap();
        assert_eq!(be.kv_stats().unwrap().used_blocks, 1, "rejected block returned");
        // The lane still decodes normally after the rollback.
        let last = *seq.last().unwrap();
        let rows = be.step(&[StepInput { lane: 0, token: last, seq: &seq }]).unwrap();
        assert!(matches!(rows[0], StepResult::Logits(_)));
        be.release(0);
    }

    #[test]
    fn native_nokv_matches_kv() {
        let model = tiny_model(412);
        let prompt = vec![9usize, 4, 21];
        let mut kv = NativeBackend::new(model.clone(), GenerationMode::KvCache, 1);
        let mut nokv = NativeBackend::new(model, GenerationMode::NoKvCache, 1);
        let a = backend_greedy(&mut kv, 0, &prompt, 5);
        let b = backend_greedy(&mut nokv, 0, &prompt, 5);
        assert_eq!(a, b, "KV and no-KV must agree on greedy tokens");
    }

    #[test]
    fn native_lanes_are_independent() {
        let model = tiny_model(413);
        let pa = vec![5usize, 17, 100];
        let pb = vec![42usize, 3, 9, 7, 1];
        let want_a = model.generate(&pa, 4);
        let want_b = model.generate(&pb, 4);
        let mut be = NativeBackend::new(model, GenerationMode::KvCache, 2);
        // Interleave the two lanes through shared iterations.
        let la = be.prefill(0, &pa).unwrap();
        let lb = be.prefill(1, &pb).unwrap();
        let mut sa = pa.clone();
        sa.push(argmax(&la));
        let mut sb = pb.clone();
        sb.push(argmax(&lb));
        for _ in 0..3 {
            let rows = be
                .step(&[
                    StepInput { lane: 0, token: *sa.last().unwrap(), seq: &sa },
                    StepInput { lane: 1, token: *sb.last().unwrap(), seq: &sb },
                ])
                .unwrap();
            sa.push(argmax(logits_of(&rows, 0)));
            sb.push(argmax(logits_of(&rows, 1)));
        }
        assert_eq!(&sa[pa.len()..], &want_a[..]);
        assert_eq!(&sb[pb.len()..], &want_b[..]);
    }

    #[test]
    fn native_released_lane_can_be_reclaimed() {
        let model = tiny_model(414);
        let prompt = vec![1usize, 2, 3];
        let want = model.generate(&prompt, 3);
        let mut be = NativeBackend::new(model, GenerationMode::KvCache, 1);
        assert_eq!(backend_greedy(&mut be, 0, &prompt, 3), want);
        // backend_greedy released lane 0; a second session reuses it.
        assert_eq!(backend_greedy(&mut be, 0, &prompt, 3), want);
    }

    #[test]
    fn native_backend_rejects_bad_lanes_and_prompts() {
        let model = tiny_model(415);
        let max = model.cfg.max_seq;
        let mut be = NativeBackend::new(model, GenerationMode::KvCache, 1);
        let beyond = be.lanes();
        assert!(be.prefill(beyond, &[1, 2]).is_err());
        assert!(be.prefill(0, &[]).is_err());
        let too_long = vec![1usize; max + 1];
        assert!(be.prefill(0, &too_long).is_err());
        // Stepping an unprefilled lane is an engine-wide typed error,
        // not a panic.
        assert!(be.step(&[StepInput { lane: 0, token: 1, seq: &[1] }]).is_err());
    }

    #[test]
    fn paged_lane_cap_exceeds_contiguous_at_equal_memory() {
        let model = tiny_model(417);
        let fixed_lanes = 4;
        let contiguous = NativeBackend::contiguous(
            model.clone(),
            GenerationMode::KvCache,
            fixed_lanes,
        );
        let paged = NativeBackend::new(model, GenerationMode::KvCache, fixed_lanes);
        assert!(
            paged.lanes() > contiguous.lanes(),
            "paged ({}) must admit more lanes than contiguous ({}) at equal memory",
            paged.lanes(),
            contiguous.lanes()
        );
    }

    #[test]
    fn shared_prefix_prefill_reuses_blocks_and_matches() {
        let model = micro_model(418, 32);
        let reference = model.clone();
        let mut be = NativeBackend::paged(
            model,
            GenerationMode::KvCache,
            PagedKvParams { block_tokens: 4, num_blocks: 16, watermark_per_active: 1 },
        );
        let prompt = vec![7usize, 3, 9, 1, 5, 2, 8, 4];
        let l0 = be.prefill(0, &prompt).unwrap();
        let stats0 = be.kv_stats().unwrap();
        let l1 = be.prefill(1, &prompt).unwrap();
        let stats1 = be.kv_stats().unwrap();
        // Same prompt: the second prefill reuses the resident prefix...
        assert!(stats1.prefix_hit_tokens > 0, "no prefix hits recorded");
        assert!(
            stats1.used_blocks <= stats0.used_blocks + 1,
            "shared prefix duplicated blocks: {} -> {}",
            stats0.used_blocks,
            stats1.used_blocks
        );
        // ...and produces bitwise-identical prefill logits.
        assert_eq!(l0, l1);
        // Both lanes then decode exactly like model.generate.
        let want = reference.generate(&prompt, 4);
        let mut s0 = prompt.clone();
        s0.push(argmax(&l0));
        let mut s1 = prompt.clone();
        s1.push(argmax(&l1));
        for _ in 0..3 {
            let rows = be
                .step(&[
                    StepInput { lane: 0, token: *s0.last().unwrap(), seq: &s0 },
                    StepInput { lane: 1, token: *s1.last().unwrap(), seq: &s1 },
                ])
                .unwrap();
            s0.push(argmax(logits_of(&rows, 0)));
            s1.push(argmax(logits_of(&rows, 1)));
        }
        assert_eq!(&s0[prompt.len()..], &want[..]);
        assert_eq!(&s1[prompt.len()..], &want[..]);
    }

    #[test]
    fn pool_exhaustion_faults_only_the_offending_lane() {
        let model = micro_model(419, 32);
        // Three blocks of four tokens: two sessions with 4-token prompts
        // each own one block; the third block is consumed by the first
        // decode wave, and the next append on one lane must fault while
        // the other lane (whose block still has a free row) advances.
        let mut be = NativeBackend::paged(
            model,
            GenerationMode::KvCache,
            PagedKvParams { block_tokens: 4, num_blocks: 3, watermark_per_active: 0 },
        );
        let pa = vec![1usize, 2, 3, 4];
        let pb = vec![5usize, 6, 7, 8];
        let la = be.prefill(0, &pa).unwrap();
        let lb = be.prefill(1, &pb).unwrap();
        let mut sa = pa.clone();
        sa.push(argmax(&la));
        let mut sb = pb.clone();
        sb.push(argmax(&lb));
        // Step 1: lane 0 grabs the last free block; lane 1 exhausts.
        let rows = be
            .step(&[
                StepInput { lane: 0, token: *sa.last().unwrap(), seq: &sa },
                StepInput { lane: 1, token: *sb.last().unwrap(), seq: &sb },
            ])
            .unwrap();
        let mut faults = 0;
        let mut ok = 0;
        for r in &rows {
            match r {
                StepResult::Logits(_) => ok += 1,
                StepResult::Fault { pos, msg } => {
                    faults += 1;
                    assert_eq!(*pos, 4, "fault at the first decode position");
                    assert!(msg.contains("exhausted"), "unexpected fault: {msg}");
                }
            }
        }
        assert_eq!((ok, faults), (1, 1), "exactly one lane faults, one advances");
        // Releasing the faulted lane frees its block for the survivor.
        be.release(1);
        sa.push(0);
        let rows = be
            .step(&[StepInput { lane: 0, token: 0, seq: &sa }])
            .unwrap();
        assert!(matches!(rows[0], StepResult::Logits(_)), "survivor keeps decoding");
        be.release(0);
    }

    fn kvlife_backend(seed: u64, life: KvLifeConfig) -> NativeBackend {
        NativeBackend::paged(
            micro_model(seed, 32),
            GenerationMode::KvCache,
            PagedKvParams { block_tokens: 4, num_blocks: 16, watermark_per_active: 1 },
        )
        .with_kvlife(life)
    }

    #[test]
    fn spill_resume_preserves_greedy_decode_bitwise() {
        let model = micro_model(421, 32);
        let reference = model.clone();
        let mut be = NativeBackend::paged(
            model,
            GenerationMode::KvCache,
            PagedKvParams { block_tokens: 4, num_blocks: 16, watermark_per_active: 1 },
        )
        .with_kvlife(KvLifeConfig {
            evict: EvictPolicyKind::Lru,
            spill: true,
            ..KvLifeConfig::default()
        });
        let prompt = vec![7usize, 3, 9, 1, 5];
        let want = reference.generate(&prompt, 6);
        let l = be.prefill(0, &prompt).unwrap();
        let mut seq = prompt.clone();
        seq.push(argmax(&l));
        for _ in 0..2 {
            let rows = be
                .step(&[StepInput { lane: 0, token: *seq.last().unwrap(), seq: &seq }])
                .unwrap();
            seq.push(argmax(logits_of(&rows, 0)));
        }
        // Preempt mid-generation, resume on a *different* lane.
        let ticket = be.spill(0).expect("paged backend with spill on must spill");
        assert_eq!(be.spill_stats().unwrap().spills, 1);
        assert!(be.spill(0).is_none(), "lane freed by the spill");
        assert!(be.resume(3, ticket).unwrap(), "pool has room to resume");
        for _ in 0..3 {
            let rows = be
                .step(&[StepInput { lane: 3, token: *seq.last().unwrap(), seq: &seq }])
                .unwrap();
            seq.push(argmax(logits_of(&rows, 0)));
        }
        assert_eq!(&seq[prompt.len()..], &want[..], "spill+resume changed greedy tokens");
        assert_eq!(be.spill_stats().unwrap().resumes, 1);
        be.release(3);
    }

    #[test]
    fn spill_is_refused_when_disabled_or_contiguous() {
        let mut off = kvlife_backend(422, KvLifeConfig::default());
        off.prefill(0, &[1, 2, 3]).unwrap();
        assert!(off.spill(0).is_none(), "spill disabled by default");
        assert!(off.spill_stats().is_none());
        off.release(0);

        let mut contiguous =
            NativeBackend::contiguous(micro_model(423, 32), GenerationMode::KvCache, 2);
        contiguous.prefill(0, &[1, 2, 3]).unwrap();
        assert!(contiguous.spill(0).is_none(), "contiguous layout cannot spill");
        assert!(contiguous.resume(1, 0).is_err());
        contiguous.release(0);
    }

    #[test]
    fn resume_defers_when_the_pool_is_tight() {
        let mut be = NativeBackend::paged(
            micro_model(424, 32),
            GenerationMode::KvCache,
            PagedKvParams { block_tokens: 4, num_blocks: 2, watermark_per_active: 0 },
        )
        .with_kvlife(KvLifeConfig { spill: true, ..KvLifeConfig::default() });
        // 8 tokens fill both blocks; + 1 decode row cannot fit back.
        be.prefill(0, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        let ticket = be.spill(0).unwrap();
        assert_eq!(be.resume(0, ticket).unwrap(), false, "no headroom for the decode row");
        // The ticket survives a refused resume and can still be dropped.
        be.drop_spilled(ticket);
        let st = be.spill_stats().unwrap();
        assert_eq!((st.spills, st.resumes, st.dropped), (1, 0, 1));
    }

    #[test]
    fn compressed_spill_resume_keeps_serving() {
        let mut be = kvlife_backend(
            425,
            KvLifeConfig {
                spill: true,
                compress: true,
                rank_frac: 0.5,
                ..KvLifeConfig::default()
            },
        );
        let prompt = vec![2usize, 9, 4, 7, 1, 3];
        let l = be.prefill(0, &prompt).unwrap();
        let mut seq = prompt.clone();
        seq.push(argmax(&l));
        let ticket = be.spill(0).unwrap();
        let st = be.spill_stats().unwrap();
        assert!(st.stored_bytes <= st.raw_bytes, "compression must never grow storage");
        assert!(be.resume(0, ticket).unwrap());
        // Lossy resume still decodes (logits, not faults).
        let rows = be
            .step(&[StepInput { lane: 0, token: *seq.last().unwrap(), seq: &seq }])
            .unwrap();
        assert!(matches!(rows[0], StepResult::Logits(_)));
        be.release(0);
    }

    #[test]
    fn paged_admit_check_gates_on_free_blocks() {
        let model = micro_model(420, 32);
        let mut be = NativeBackend::paged(
            model,
            GenerationMode::KvCache,
            PagedKvParams { block_tokens: 4, num_blocks: 4, watermark_per_active: 1 },
        );
        // Empty pool admits.
        assert_eq!(be.admit_check(4, 4), AdmitVerdict::Admit);
        // A session that could never fit is rejected outright.
        assert!(matches!(be.admit_check(13, 10), AdmitVerdict::Reject(_)));
        // Fill most of the pool; the watermark defers further admissions.
        be.prefill(0, &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10]).unwrap();
        assert_eq!(be.admit_check(4, 4), AdmitVerdict::Defer);
        be.release(0);
        assert_eq!(be.admit_check(4, 4), AdmitVerdict::Admit);
    }
}
