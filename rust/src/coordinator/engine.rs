//! The generation engine: prefill + batched KV-cache decode (or the no-KV
//! re-prefill mode) over a [`ModelRunner`].

use crate::runtime::exec::{argmax, KvState, ModelRunner};
use crate::runtime::loader::literal_f32;
use crate::runtime::Engine;
use anyhow::{bail, Result};
use std::time::{Duration, Instant};

/// Whether decode reuses the KV cache (Table 7's "Use KV Cache" axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GenerationMode {
    /// Prefill once, then one decode step per token (cache reused).
    KvCache,
    /// Re-run the full prefill for every generated token — the paper's
    /// no-cache row (and what 2:4 sparse models are forced into when the
    /// sparse kernel can't run the cache ops).
    NoKvCache,
}

/// Greedy generation over one bound model artifact pair.
pub struct GenerationEngine {
    pub runner: ModelRunner,
    pub mode: GenerationMode,
}

impl GenerationEngine {
    pub fn new(runner: ModelRunner, mode: GenerationMode) -> Self {
        Self { runner, mode }
    }

    /// Generate for a batch of equal-length prompts (padded internally to
    /// the decode artifact's batch). Returns per-prompt new tokens and the
    /// execution wall time.
    pub fn generate_batch(
        &self,
        engine: &mut Engine,
        prompts: &[Vec<usize>],
        max_new: usize,
    ) -> Result<(Vec<Vec<usize>>, Duration)> {
        if prompts.is_empty() {
            return Ok((Vec::new(), Duration::ZERO));
        }
        let len0 = prompts[0].len();
        if prompts.iter().any(|p| p.len() != len0) {
            bail!("generate_batch requires equal-length prompts");
        }
        if prompts.len() > self.runner.batch {
            bail!("batch {} exceeds artifact batch {}", prompts.len(), self.runner.batch);
        }
        let t0 = Instant::now();
        let out = match self.mode {
            GenerationMode::KvCache => self.run_kv(engine, prompts, max_new)?,
            GenerationMode::NoKvCache => self.run_nokv(engine, prompts, max_new)?,
        };
        Ok((out, t0.elapsed()))
    }

    fn run_kv(
        &self,
        engine: &mut Engine,
        prompts: &[Vec<usize>],
        max_new: usize,
    ) -> Result<Vec<Vec<usize>>> {
        let b_art = self.runner.batch;
        let len0 = prompts[0].len();
        // Prefill each real prompt (B=1 artifact); batch-pad with prompt 0.
        let mut ks: Vec<Vec<f32>> = Vec::with_capacity(b_art);
        let mut vs: Vec<Vec<f32>> = Vec::with_capacity(b_art);
        let mut next: Vec<usize> = Vec::with_capacity(b_art);
        for bi in 0..b_art {
            let prompt = prompts.get(bi).unwrap_or(&prompts[0]);
            let (logits, kv) = self.runner.prefill(engine, prompt)?;
            next.push(argmax(&self.runner.logits_at(&logits, prompt.len() - 1)));
            ks.push(kv.k.to_vec::<f32>()?);
            vs.push(kv.v.to_vec::<f32>()?);
        }
        // Merge per-sequence (L,1,S,d) caches into (L,B,S,d).
        let (l, s, d) = (self.runner.layers, self.runner.max_seq, self.runner.dim);
        let stride = s * d;
        let mut kbuf = vec![0f32; l * b_art * stride];
        let mut vbuf = vec![0f32; l * b_art * stride];
        for li in 0..l {
            for (bi, (kseq, vseq)) in ks.iter().zip(vs.iter()).enumerate() {
                let src = li * stride..(li + 1) * stride;
                let dst = (li * b_art + bi) * stride..(li * b_art + bi + 1) * stride;
                kbuf[dst.clone()].copy_from_slice(&kseq[src.clone()]);
                vbuf[dst].copy_from_slice(&vseq[src]);
            }
        }
        let dims = [l, b_art, s, d];
        let mut state = KvState {
            k: literal_f32(&kbuf, &dims)?,
            v: literal_f32(&vbuf, &dims)?,
            pos: len0,
        };
        let mut outputs: Vec<Vec<usize>> = vec![Vec::new(); prompts.len()];
        for step in 0..max_new {
            for (bi, out) in outputs.iter_mut().enumerate() {
                out.push(next[bi]);
            }
            if step + 1 == max_new || state.pos >= self.runner.max_seq {
                break;
            }
            let (logits, new_state) = self.runner.decode_step(engine, state, &next)?;
            state = new_state;
            for (bi, row) in logits.iter().enumerate() {
                next[bi] = argmax(row);
            }
        }
        Ok(outputs)
    }

    fn run_nokv(
        &self,
        engine: &mut Engine,
        prompts: &[Vec<usize>],
        max_new: usize,
    ) -> Result<Vec<Vec<usize>>> {
        let mut outputs: Vec<Vec<usize>> = vec![Vec::new(); prompts.len()];
        for (bi, prompt) in prompts.iter().enumerate() {
            let mut seq = prompt.clone();
            for _ in 0..max_new {
                if seq.len() >= self.runner.prefill_seq {
                    break;
                }
                // Full re-prefill every step — the no-cache cost.
                let (logits, _) = self.runner.prefill(engine, &seq)?;
                let next = argmax(&self.runner.logits_at(&logits, seq.len() - 1));
                outputs[bi].push(next);
                seq.push(next);
            }
        }
        Ok(outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::model::config::ModelConfig;
    use crate::model::transformer::Transformer;
    use std::path::Path;

    fn artifact_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have(name: &str) -> bool {
        artifact_dir().join(format!("{name}.hlo.txt")).exists()
    }

    #[test]
    fn kv_generation_matches_native_greedy() {
        if !have("tiny-s_dense_prefill_b1_t64") {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut engine = Engine::new(&artifact_dir()).unwrap();
        let cfg = ModelConfig::tiny_s();
        let mut rng = Rng::new(411);
        let model = Transformer::new_random(&cfg, &mut rng);
        let runner = ModelRunner::new(
            &mut engine,
            &model,
            "tiny-s_dense_prefill_b1_t64",
            "tiny-s_dense_decode_b1",
        )
        .unwrap();
        let gen = GenerationEngine::new(runner, GenerationMode::KvCache);
        let prompt = vec![3usize, 11, 7, 2];
        let (outs, _) = gen.generate_batch(&mut engine, &[prompt.clone()], 6).unwrap();
        let native = model.generate(&prompt, 6);
        assert_eq!(outs[0], native, "PJRT greedy decode diverged from native");
    }

    #[test]
    fn nokv_generation_matches_kv() {
        if !have("tiny-s_dense_prefill_b1_t64") {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut engine = Engine::new(&artifact_dir()).unwrap();
        let cfg = ModelConfig::tiny_s();
        let mut rng = Rng::new(412);
        let model = Transformer::new_random(&cfg, &mut rng);
        let mk = |engine: &mut Engine| {
            ModelRunner::new(
                engine,
                &model,
                "tiny-s_dense_prefill_b1_t64",
                "tiny-s_dense_decode_b1",
            )
            .unwrap()
        };
        let prompt = vec![9usize, 4, 21];
        let kv = GenerationEngine::new(mk(&mut engine), GenerationMode::KvCache);
        let (a, t_kv) = kv.generate_batch(&mut engine, &[prompt.clone()], 5).unwrap();
        let nokv = GenerationEngine::new(mk(&mut engine), GenerationMode::NoKvCache);
        let (b, t_nokv) = nokv.generate_batch(&mut engine, &[prompt], 5).unwrap();
        assert_eq!(a, b, "KV and no-KV must agree on greedy tokens");
        // Not asserted (timing noise on CI), but typically t_nokv > t_kv.
        let _ = (t_kv, t_nokv);
    }

    #[test]
    fn rejects_ragged_batches() {
        if !have("tiny-s_dense_prefill_b1_t64") {
            return;
        }
        let mut engine = Engine::new(&artifact_dir()).unwrap();
        let cfg = ModelConfig::tiny_s();
        let mut rng = Rng::new(413);
        let model = Transformer::new_random(&cfg, &mut rng);
        let runner = ModelRunner::new(
            &mut engine,
            &model,
            "tiny-s_dense_prefill_b1_t64",
            "tiny-s_dense_decode_b1",
        )
        .unwrap();
        let gen = GenerationEngine::new(runner, GenerationMode::KvCache);
        let r = gen.generate_batch(&mut engine, &[vec![1, 2], vec![1, 2, 3]], 2);
        assert!(r.is_err());
    }
}
