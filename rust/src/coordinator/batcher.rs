//! Dynamic batcher: groups queued requests into batches of the decode
//! artifact's static batch size, waiting up to `max_wait` to fill a batch
//! (the standard continuous-serving trade-off between latency and
//! occupancy).

use super::request::GenRequest;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batcher policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Target batch size (the decode artifact's static batch).
    pub max_batch: usize,
    /// Max time the oldest request may wait before a partial batch ships.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// FIFO queue + batch formation.
pub struct Batcher {
    cfg: BatcherConfig,
    queue: VecDeque<GenRequest>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self { cfg, queue: VecDeque::new() }
    }

    pub fn push(&mut self, mut req: GenRequest) {
        if req.arrived.is_none() {
            req.arrived = Some(Instant::now());
        }
        self.queue.push_back(req);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch be formed *now*? Either the queue can fill a batch,
    /// or the oldest request has waited past the budget.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.cfg.max_batch {
            return true;
        }
        match self.queue.front().and_then(|r| r.arrived) {
            Some(t0) => now.duration_since(t0) >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Pop up to `max_batch` requests.
    pub fn take_batch(&mut self) -> Vec<GenRequest> {
        let n = self.queue.len().min(self.cfg.max_batch);
        self.queue.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> GenRequest {
        GenRequest::new(id, vec![1, 2], 4)
    }

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batcher::new(BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(60) });
        for i in 0..5 {
            b.push(req(i));
        }
        assert!(b.ready(Instant::now()));
        let batch = b.take_batch();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 0);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn partial_batch_ships_after_wait() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        });
        b.push(req(0));
        assert!(!b.ready(Instant::now()));
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch().len(), 1);
    }

    #[test]
    fn empty_is_never_ready() {
        let b = Batcher::new(BatcherConfig::default());
        assert!(!b.ready(Instant::now()));
        assert!(b.is_empty());
    }
}
