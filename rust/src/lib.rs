//! # pifa — Pivoting Factorization (ICML 2025) reproduction
//!
//! A three-layer Rust + JAX + Pallas reproduction of *Pivoting
//! Factorization: A Compact Meta Low-Rank Representation of Sparsity for
//! Efficient Inference in Large Language Models* (Zhao, Zhang, Cannistraci).
//!
//! Layer map (see DESIGN.md):
//! * [`linalg`] — from-scratch dense linear algebra (GEMM, pivoted QR, LU,
//!   Cholesky, Jacobi SVD, solvers, RNG).
//! * [`pifa`] — the paper's core contribution: Pivoting Factorization
//!   (Algorithm 1), the PIFA layer (Algorithm 2), and cost accounting.
//! * [`compress`] — SVD-LLM whitening + the Online
//!   Error-Accumulation-Minimization Reconstruction (M) + the end-to-end
//!   MPIFA driver (Algorithm 3), fronted by the staged
//!   [`compress::pipeline`] (Calibrate → Prune → Reconstruct → Factorize
//!   → Pack) and the name-based [`compress::registry`] every consumer
//!   dispatches through.
//! * [`baselines`] — every comparator in the paper's evaluation.
//! * [`sparse24`] — 2:4 semi-structured sparsity substrate.
//! * [`model`] / [`train`] / [`data`] / [`eval`] — the tiny-LLaMA stand-in
//!   models, trainer, synthetic corpora and evaluation harnesses.
//! * [`runtime`] / [`coordinator`] — PJRT artifact execution, the kernel
//!   layer (`runtime::kernels`: persistent thread pool + structure-aware
//!   decode fast paths, DESIGN.md §7) + the serving coordinator
//!   (generation sessions, iteration-level scheduler, streaming server).
//! * [`bench`] — the criterion-less benchmark harness used by
//!   `rust/benches/*` to regenerate every paper table/figure.

pub mod linalg;
pub mod pifa;
// modules enabled incrementally as they land
pub mod compress;
pub mod baselines;
pub mod sparse24;
pub mod model;
pub mod train;
pub mod data;
pub mod eval;
pub mod runtime;
pub mod coordinator;
pub mod bench;
