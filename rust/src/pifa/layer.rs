//! Algorithm 2 — the PIFA inference layer.
//!
//! Stores `(I, W_p, C)` and computes `Y = W' X` without ever materializing
//! `W'`:
//!
//! ```text
//! Y_p  = W_p X            2 r n b   FLOPs
//! Y_np = C  Y_p           2 r (m-r) b
//! Y[I, :]   = Y_p
//! Y[I^c, :] = Y_np        total: 2 b r (m + n - r)
//! ```
//!
//! Two memory layouts are provided: `apply_cols` follows the paper's
//! `X ∈ R^{n x b}` convention; `apply_rows` is the transformer-friendly
//! `X ∈ R^{b x n} → Y = X W'^T ∈ R^{b x m}` used by `crate::model`.
//! `apply_rows` dispatches decode-sized batches to the fused one-pass
//! kernel in `crate::runtime::kernels::fused` (DESIGN.md §7).

use crate::linalg::{self, Mat, Scalar};

/// A factored PIFA layer: pivot indices, pivot-row matrix, coefficients.
#[derive(Clone)]
pub struct PifaLayer<T: Scalar = f32> {
    /// Output dimension `m` of the original `W' (m x n)`.
    pub m: usize,
    /// Input dimension `n`.
    pub n: usize,
    /// Pivot-row indices `I` (length r, in pivot order).
    pub pivots: Vec<usize>,
    /// Non-pivot row indices `I^c` (length m - r, ascending).
    pub non_pivots: Vec<usize>,
    /// Pivot-row matrix `W_p (r x n)`.
    pub w_p: Mat<T>,
    /// Coefficient matrix `C ((m-r) x r)` with `W_np = C W_p`.
    pub c: Mat<T>,
}

impl<T: Scalar> PifaLayer<T> {
    pub fn new(
        m: usize,
        n: usize,
        pivots: Vec<usize>,
        non_pivots: Vec<usize>,
        w_p: Mat<T>,
        c: Mat<T>,
    ) -> Self {
        let r = pivots.len();
        debug_assert_eq!(w_p.shape(), (r, n));
        debug_assert_eq!(c.shape(), (m - r, r));
        debug_assert_eq!(non_pivots.len(), m - r);
        Self { m, n, pivots, non_pivots, w_p, c }
    }

    /// Rank of the factorization.
    pub fn rank(&self) -> usize {
        self.pivots.len()
    }

    /// Stored parameter count: `r(m + n) - r^2 + r` (§3.3), excluding the
    /// (negligible) integer index vector.
    pub fn param_count(&self) -> usize {
        self.w_p.rows() * self.w_p.cols() + self.c.rows() * self.c.cols()
    }

    /// Density relative to the dense `m x n` matrix.
    pub fn density(&self) -> f64 {
        self.param_count() as f64 / (self.m * self.n) as f64
    }

    /// FLOPs for a batch of `b` columns (2 b r (m + n - r), §3.3).
    pub fn flops(&self, b: usize) -> usize {
        super::costs::pifa_flops(self.m, self.n, self.rank(), b)
    }

    /// Paper layout: `X (n x b) → Y (m x b)`.
    pub fn apply_cols(&self, x: &Mat<T>) -> Mat<T> {
        assert_eq!(x.rows(), self.n, "PifaLayer::apply_cols: input dim mismatch");
        let b = x.cols();
        let y_p = linalg::matmul(&self.w_p, x); // r x b
        let y_np = linalg::matmul(&self.c, &y_p); // (m-r) x b
        let mut y = Mat::zeros(self.m, b);
        for (k, &i) in self.pivots.iter().enumerate() {
            y.row_mut(i).copy_from_slice(y_p.row(k));
        }
        for (k, &i) in self.non_pivots.iter().enumerate() {
            y.row_mut(i).copy_from_slice(y_np.row(k));
        }
        y
    }

    /// Transformer layout: `X (b x n) → Y = X W'^T (b x m)`.
    ///
    /// Decode batches (`b <=` [`kernels::DECODE_BATCH_MAX`]) take the
    /// fused one-pass kernel ([`kernels::fused::pifa_apply_rows_fused`]);
    /// larger batches run the unfused two-GEMM path. Both are
    /// differentially tested against each other and against the dense
    /// reference.
    ///
    /// [`kernels::DECODE_BATCH_MAX`]: crate::runtime::kernels::DECODE_BATCH_MAX
    /// [`kernels::fused::pifa_apply_rows_fused`]: crate::runtime::kernels::fused::pifa_apply_rows_fused
    pub fn apply_rows(&self, x: &Mat<T>) -> Mat<T> {
        if x.rows() <= crate::runtime::kernels::DECODE_BATCH_MAX {
            return crate::runtime::kernels::fused::pifa_apply_rows_fused(self, x);
        }
        self.apply_rows_unfused(x)
    }

    /// The generic two-GEMM apply: `Y_p = X W_p^T (b x r)`,
    /// `Y_np = Y_p C^T (b x (m-r))`, then the two results are interleaved
    /// into the output columns by pivot index. Kept callable as the
    /// reference the fused kernel is differentially tested against.
    pub fn apply_rows_unfused(&self, x: &Mat<T>) -> Mat<T> {
        assert_eq!(x.cols(), self.n, "PifaLayer::apply_rows: input dim mismatch");
        let b = x.rows();
        let y_p = linalg::matmul_nt(x, &self.w_p); // b x r
        let y_np = linalg::matmul_nt(&y_p, &self.c); // b x (m-r)
        let mut y = Mat::zeros(b, self.m);
        for row in 0..b {
            let yp_row = y_p.row(row);
            let ynp_row = y_np.row(row);
            let y_row = y.row_mut(row);
            for (k, &i) in self.pivots.iter().enumerate() {
                y_row[i] = yp_row[k];
            }
            for (k, &i) in self.non_pivots.iter().enumerate() {
                y_row[i] = ynp_row[k];
            }
        }
        y
    }

    /// Materialize `W'` (testing / export only — never on the hot path).
    pub fn reconstruct(&self) -> Mat<T> {
        let w_np = linalg::matmul(&self.c, &self.w_p);
        let mut w = Mat::zeros(self.m, self.n);
        for (k, &i) in self.pivots.iter().enumerate() {
            w.row_mut(i).copy_from_slice(self.w_p.row(k));
        }
        for (k, &i) in self.non_pivots.iter().enumerate() {
            w.row_mut(i).copy_from_slice(w_np.row(k));
        }
        w
    }

    /// Precision conversion.
    pub fn cast<U: Scalar>(&self) -> PifaLayer<U> {
        PifaLayer {
            m: self.m,
            n: self.n,
            pivots: self.pivots.clone(),
            non_pivots: self.non_pivots.clone(),
            w_p: self.w_p.cast(),
            c: self.c.cast(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::pifa::factorize::{pivoting_factorization, PivotStrategy};

    fn make_layer(m: usize, n: usize, r: usize, seed: u64) -> (Mat<f64>, PifaLayer<f64>) {
        let mut rng = Rng::new(seed);
        let w: Mat<f64> = Mat::rand_low_rank(m, n, r, &mut rng);
        let layer = pivoting_factorization(&w, r, PivotStrategy::QrColumnPivot).unwrap();
        (w, layer)
    }

    #[test]
    fn apply_cols_matches_dense() {
        let (w, layer) = make_layer(24, 16, 6, 91);
        let mut rng = Rng::new(92);
        let x: Mat<f64> = Mat::randn(16, 5, &mut rng);
        let y_dense = linalg::matmul(&w, &x);
        let y_pifa = layer.apply_cols(&x);
        assert!(y_pifa.rel_fro_err(&y_dense) < 1e-10);
    }

    #[test]
    fn apply_rows_matches_dense() {
        let (w, layer) = make_layer(24, 16, 6, 93);
        let mut rng = Rng::new(94);
        let x: Mat<f64> = Mat::randn(7, 16, &mut rng);
        let y_dense = linalg::matmul_nt(&x, &w); // X W^T
        let y_pifa = layer.apply_rows(&x);
        assert!(y_pifa.rel_fro_err(&y_dense) < 1e-10);
    }

    #[test]
    fn fused_and_unfused_agree_across_the_dispatch_boundary() {
        let (_, layer) = make_layer(24, 16, 6, 101);
        let mut rng = Rng::new(102);
        for b in 1..=6 {
            let x: Mat<f64> = Mat::randn(b, 16, &mut rng);
            let y = layer.apply_rows(&x); // b <= 4 dispatches to the fused kernel
            let y_ref = layer.apply_rows_unfused(&x);
            assert!(y.rel_fro_err(&y_ref) < 1e-11, "b={b}: {}", y.rel_fro_err(&y_ref));
        }
    }

    #[test]
    fn apply_layouts_agree() {
        let (_, layer) = make_layer(20, 12, 4, 95);
        let mut rng = Rng::new(96);
        let x_cols: Mat<f64> = Mat::randn(12, 9, &mut rng);
        let y1 = layer.apply_cols(&x_cols);
        let y2 = layer.apply_rows(&x_cols.transpose()).transpose();
        assert!(y1.rel_fro_err(&y2) < 1e-12);
    }

    #[test]
    fn param_count_formula() {
        let (_, layer) = make_layer(32, 24, 8, 97);
        let (m, n, r) = (32usize, 24usize, 8usize);
        assert_eq!(layer.param_count(), r * n + (m - r) * r);
        assert_eq!(layer.param_count(), r * (m + n) - r * r);
        // §3.3 formula includes +r for the index vector; param_count
        // counts only float storage, costs::pifa_params adds the index.
        assert_eq!(super::super::costs::pifa_params(m, n, r), r * (m + n) - r * r + r);
    }

    #[test]
    fn density_below_one_for_any_valid_rank() {
        for &(m, n) in &[(16usize, 16usize), (32, 8), (8, 32)] {
            for r in 1..m.min(n) {
                let mut rng = Rng::new(100 + r as u64);
                let w: Mat<f64> = Mat::rand_low_rank(m, n, r, &mut rng);
                let layer = pivoting_factorization(&w, r, PivotStrategy::QrColumnPivot).unwrap();
                assert!(
                    layer.density() < 1.0,
                    "PIFA density must beat dense: ({m},{n},{r}) -> {}",
                    layer.density()
                );
            }
        }
    }

    #[test]
    fn flops_less_than_lowrank() {
        let (_, layer) = make_layer(32, 32, 16, 98);
        let b = 4;
        assert!(layer.flops(b) < super::super::costs::lowrank_flops(32, 32, 16, b));
    }

    #[test]
    fn cast_roundtrip_small_error() {
        let (w, layer) = make_layer(16, 16, 4, 99);
        let l32: PifaLayer<f32> = layer.cast();
        let rec = l32.reconstruct().cast::<f64>();
        assert!(rec.rel_fro_err(&w) < 1e-4);
    }
}
