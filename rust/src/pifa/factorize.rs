//! Algorithm 1 — Pivoting Factorization.
//!
//! Given a singular (rank-r) matrix `W' = U V^T`, find `r` linearly
//! independent rows (**pivot rows**), and express every other row as a
//! linear combination of them:
//!
//! ```text
//! W_p  = W'[I, :]          (r x n)     pivot-row matrix
//! W_np = W'[I^c, :]        ((m-r) x n) non-pivot rows
//! C    : W_np = C W_p      ((m-r) x r) coefficient matrix
//! ```
//!
//! Pivot selection uses QR with column pivoting on `W'^T` (Businger–Golub),
//! which greedily picks the row with the largest residual norm — a
//! well-conditioned spanning set. LU with partial pivoting is provided as
//! the paper's stated alternative (`PivotStrategy::Lu`).
//!
//! The factorization is **lossless**: for an exactly rank-r input the
//! reconstruction `scatter(W_p, C W_p)` equals `W'` to floating-point
//! round-off (tested below, and property-tested in `rust/tests/`).

use crate::linalg::{self, Mat, Scalar};
use anyhow::{ensure, Context, Result};

use super::layer::PifaLayer;

/// How pivot rows are selected (paper Algorithm 1 step 1 allows either).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PivotStrategy {
    /// QR with column pivoting on `W'^T` (default; best conditioned).
    QrColumnPivot,
    /// LU with partial (row) pivoting on `W'`.
    Lu,
}

/// Run Pivoting Factorization on a low-rank matrix `w` with target rank `r`.
///
/// `w` is expected to be (numerically) rank `r`; rows beyond the pivot set
/// are reproduced exactly as linear combinations. Returns the complete
/// [`PifaLayer`] (pivot indices, `W_p`, `C`).
pub fn pivoting_factorization<T: Scalar>(
    w: &Mat<T>,
    r: usize,
    strategy: PivotStrategy,
) -> Result<PifaLayer<T>> {
    let (m, n) = w.shape();
    ensure!(r >= 1, "pivoting_factorization: rank must be >= 1");
    ensure!(r <= m.min(n), "pivoting_factorization: rank {r} exceeds min dim {}", m.min(n));

    // Step 1: pivot-row indices.
    let pivots = match strategy {
        PivotStrategy::QrColumnPivot => {
            let wt = w.transpose();
            let f = linalg::qr_column_pivot(&wt);
            f.pivots(r)
        }
        PivotStrategy::Lu => {
            let f = linalg::lu_decompose(w);
            f.pivot_rows(r)
        }
    };
    debug_assert_eq!(pivots.len(), r);

    // Step 2/3: split rows into pivot and non-pivot sets.
    let mut is_pivot = vec![false; m];
    for &i in &pivots {
        is_pivot[i] = true;
    }
    let non_pivots: Vec<usize> = (0..m).filter(|&i| !is_pivot[i]).collect();
    let w_p = w.select_rows(&pivots);
    let w_np = w.select_rows(&non_pivots);

    // Step 5: solve W_np = C W_p  =>  C = W_np W_p^T (W_p W_p^T)^{-1}.
    // The Gram matrix is SPD because pivot rows are linearly independent.
    // Solve (W_p W_p^T) Z = W_p W_np^T in f64, then C = Z^T.
    let w_p64 = w_p.cast::<f64>();
    let w_np64 = w_np.cast::<f64>();
    let gram = linalg::matmul_nt(&w_p64, &w_p64); // r x r
    let rhs = linalg::matmul_nt(&w_p64, &w_np64); // r x (m - r)
    let z = linalg::chol_solve(&gram, &rhs)
        .or_else(|_| {
            // Near-singular Gram (rank over-estimate): tiny ridge fallback.
            linalg::ridge_solve_spd(&gram, gram.max_abs().max(1e-300) * 1e-12, &rhs)
        })
        .context("pivoting_factorization: coefficient solve failed")?;
    let c = z.transpose().cast::<T>();

    Ok(PifaLayer::new(m, n, pivots, non_pivots, w_p, c))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn lossless_check(m: usize, n: usize, r: usize, strategy: PivotStrategy, seed: u64, tol: f64) {
        let mut rng = Rng::new(seed);
        let w: Mat<f64> = Mat::rand_low_rank(m, n, r, &mut rng);
        let layer = pivoting_factorization(&w, r, strategy).unwrap();
        let rec = layer.reconstruct();
        assert!(
            rec.rel_fro_err(&w) < tol,
            "({m},{n},r={r},{strategy:?}) err={}",
            rec.rel_fro_err(&w)
        );
    }

    #[test]
    fn lossless_qr_various_shapes() {
        lossless_check(16, 12, 4, PivotStrategy::QrColumnPivot, 71, 1e-10);
        lossless_check(12, 16, 4, PivotStrategy::QrColumnPivot, 72, 1e-10);
        lossless_check(32, 32, 16, PivotStrategy::QrColumnPivot, 73, 1e-10);
        lossless_check(64, 48, 24, PivotStrategy::QrColumnPivot, 74, 1e-9);
    }

    #[test]
    fn lossless_lu() {
        lossless_check(20, 14, 5, PivotStrategy::Lu, 75, 1e-9);
    }

    #[test]
    fn full_rank_square_is_permutation_decomposition() {
        // r = m = n: every row is a pivot row; C is empty; reconstruction
        // is just the row gather/scatter identity.
        let mut rng = Rng::new(76);
        let w: Mat<f64> = Mat::randn(8, 8, &mut rng);
        let layer = pivoting_factorization(&w, 8, PivotStrategy::QrColumnPivot).unwrap();
        assert_eq!(layer.c.rows(), 0);
        assert!(layer.reconstruct().rel_fro_err(&w) < 1e-12);
    }

    #[test]
    fn rank_one() {
        lossless_check(10, 10, 1, PivotStrategy::QrColumnPivot, 77, 1e-10);
    }

    #[test]
    fn pivot_indices_are_unique_and_in_range() {
        let mut rng = Rng::new(78);
        let w: Mat<f64> = Mat::rand_low_rank(30, 20, 9, &mut rng);
        let layer = pivoting_factorization(&w, 9, PivotStrategy::QrColumnPivot).unwrap();
        let mut seen = vec![false; 30];
        for &i in &layer.pivots {
            assert!(i < 30);
            assert!(!seen[i], "duplicate pivot {i}");
            seen[i] = true;
        }
        assert_eq!(layer.pivots.len() + layer.non_pivots.len(), 30);
    }

    #[test]
    fn rejects_bad_rank() {
        let w: Mat<f64> = Mat::zeros(4, 4);
        assert!(pivoting_factorization(&w, 0, PivotStrategy::QrColumnPivot).is_err());
        assert!(pivoting_factorization(&w, 5, PivotStrategy::QrColumnPivot).is_err());
    }

    #[test]
    fn f32_inputs_round_trip() {
        let mut rng = Rng::new(79);
        let w: Mat<f32> = Mat::rand_low_rank(24, 16, 6, &mut rng);
        let layer = pivoting_factorization(&w, 6, PivotStrategy::QrColumnPivot).unwrap();
        assert!(layer.reconstruct().rel_fro_err(&w) < 1e-4);
    }

    #[test]
    fn qr_beats_or_matches_lu_conditioning() {
        // On a matrix with wildly scaled rows, QR pivoting should still pick
        // an independent set; verify both reconstruct.
        let mut rng = Rng::new(80);
        let mut w: Mat<f64> = Mat::rand_low_rank(20, 20, 5, &mut rng);
        for i in 0..20 {
            let s = 10f64.powi((i % 7) as i32 - 3);
            for j in 0..20 {
                w[(i, j)] *= s;
            }
        }
        for strat in [PivotStrategy::QrColumnPivot, PivotStrategy::Lu] {
            let layer = pivoting_factorization(&w, 5, strat).unwrap();
            assert!(layer.reconstruct().rel_fro_err(&w) < 1e-6, "{strat:?}");
        }
    }
}
