//! Parameter and FLOP accounting (§3.3), density↔rank mapping (DESIGN.md
//! §5), and the Figure 3 structure comparison (LU / QR / PIFA non-trivial
//! parameter layouts).

/// Dense `m x n` parameter count.
pub fn dense_params(m: usize, n: usize) -> usize {
    m * n
}

/// Traditional low-rank `U V^T` parameter count: `r (m + n)`.
pub fn lowrank_params(m: usize, n: usize, r: usize) -> usize {
    let _ = n;
    r * (m + n)
}

/// PIFA parameter count: `r(m + n) - r^2 + r` — `W_p` is `r x n`, `C` is
/// `(m - r) x r`, plus the `r` pivot indices (§3.3).
pub fn pifa_params(m: usize, n: usize, r: usize) -> usize {
    r * n + (m - r) * r + r
}

/// Dense layer FLOPs for batch `b`: `2 m n b`.
pub fn dense_flops(m: usize, n: usize, b: usize) -> usize {
    2 * m * n * b
}

/// Low-rank layer FLOPs: `2 b r (m + n)`.
pub fn lowrank_flops(m: usize, n: usize, r: usize, b: usize) -> usize {
    2 * b * r * (m + n)
}

/// PIFA layer FLOPs: `2 b r (m + n - r)` (§3.3).
pub fn pifa_flops(m: usize, n: usize, r: usize, b: usize) -> usize {
    2 * b * r * (m + n - r)
}

/// Rank that a *low-rank* layer may use at parameter density `rho`:
/// `r = rho * m n / (m + n)` (rounded, clamped to [1, min(m,n)]).
pub fn rank_for_density_lowrank(m: usize, n: usize, rho: f64) -> usize {
    let r = rho * (m * n) as f64 / (m + n) as f64;
    (r.round() as usize).clamp(1, m.min(n))
}

/// Rank that a *PIFA* layer may use at density `rho`: the smaller root of
/// `r^2 - r(m + n + 1) + rho m n = 0` (PIFA's savings are spent on extra
/// rank — this is why W+M+PIFA beats W+M at equal density in Table 5).
pub fn rank_for_density_pifa(m: usize, n: usize, rho: f64) -> usize {
    let b = (m + n + 1) as f64;
    let c = rho * (m * n) as f64;
    let disc = (b * b - 4.0 * c).max(0.0).sqrt();
    let r = (b - disc) / 2.0;
    (r.round() as usize).clamp(1, m.min(n))
}

/// Density of a PIFA layer at rank `r`.
pub fn density_of_pifa_rank(m: usize, n: usize, r: usize) -> f64 {
    pifa_params(m, n, r) as f64 / dense_params(m, n) as f64
}

/// Density of a low-rank layer at rank `r`.
pub fn density_of_lowrank_rank(m: usize, n: usize, r: usize) -> f64 {
    lowrank_params(m, n, r) as f64 / dense_params(m, n) as f64
}

/// Figure 3: non-trivial parameter counts of rank-r factorizations of a
/// (row-permuted) `m x n` rank-r matrix.
///
/// * LU keeps `r(m + n) - r^2 + r` non-trivial entries but distributes the
///   `L` part as a trapezoid (unit diagonal preset) — bad for GPU tiling.
/// * QR stores `Q (m x r)` dense + `R (r x n)` upper-trapezoid → more
///   parameters and the R-triangle is still non-rectangular.
/// * PIFA reorganizes into two dense rectangles `W_p (r x n)`, `C ((m-r) x r)`.
#[derive(Clone, Copy, Debug)]
pub struct StructureCounts {
    pub nontrivial: usize,
    /// Entries preset to 0 or 1 by the format (no storage needed).
    pub trivial: usize,
    /// True when all non-trivial entries form dense rectangles (GPU-friendly).
    pub rectangular: bool,
}

/// LU factor layout of the permuted rank-r matrix: `L` is `m x r` unit
/// lower-trapezoidal, `U` is `r x n` upper-trapezoidal.
pub fn lu_structure(m: usize, n: usize, r: usize) -> StructureCounts {
    // L: column j has (m - j - 1) sub-diagonal entries + unit diagonal.
    let l_nontrivial: usize = (0..r).map(|j| m - j - 1).sum();
    // U: row i has (n - i) entries from the diagonal right.
    let u_nontrivial: usize = (0..r).map(|i| n - i).sum();
    let trivial = r // unit diagonal of L
        + (0..r).map(|i| i).sum::<usize>() // zeros below U's diagonal
        + (0..r).map(|j| j).sum::<usize>(); // zeros above L's diagonal
    StructureCounts {
        nontrivial: l_nontrivial + u_nontrivial,
        trivial,
        rectangular: false,
    }
}

/// QR layout: `Q (m x r)` dense, `R (r x n)` upper-trapezoidal.
pub fn qr_structure(m: usize, n: usize, r: usize) -> StructureCounts {
    let q = m * r;
    let r_nontrivial: usize = (0..r).map(|i| n - i).sum();
    StructureCounts { nontrivial: q + r_nontrivial, trivial: (0..r).map(|i| i).sum(), rectangular: false }
}

/// PIFA layout: `W_p (r x n)` and `C ((m-r) x r)`, both dense rectangles.
pub fn pifa_structure(m: usize, n: usize, r: usize) -> StructureCounts {
    StructureCounts { nontrivial: r * n + (m - r) * r, trivial: 0, rectangular: true }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pifa_always_cheaper_than_lowrank() {
        for &(m, n) in &[(64usize, 64usize), (128, 32), (32, 128)] {
            for r in 1..=m.min(n) {
                assert!(pifa_params(m, n, r) < lowrank_params(m, n, r) + r + 1);
                assert!(
                    pifa_params(m, n, r) - r < lowrank_params(m, n, r),
                    "float params must be strictly fewer"
                );
            }
        }
    }

    #[test]
    fn pifa_always_cheaper_than_dense() {
        // Eq. 3: (m - r)(n - r) > 0  =>  mn > r(m+n) - r^2.
        for &(m, n) in &[(64usize, 64usize), (100, 40)] {
            for r in 1..m.min(n) {
                assert!(pifa_params(m, n, r) - r < dense_params(m, n));
            }
        }
    }

    #[test]
    fn lowrank_exceeds_dense_above_half() {
        // Figure 1: low-rank storage passes dense at r > mn/(m+n).
        let (m, n) = (128usize, 128usize);
        let r_cross = m * n / (m + n); // = 64
        assert!(lowrank_params(m, n, r_cross + 8) > dense_params(m, n));
        assert!(lowrank_params(m, n, r_cross - 8) < dense_params(m, n));
    }

    #[test]
    fn paper_headline_savings_at_half_rank() {
        // At r/d = 0.5 on square d x d: PIFA saves (r^2 - r) of r(m+n) —
        // the paper reports 24.2% memory savings over low-rank at r = d/2.
        let d = 8192usize;
        let r = d / 2;
        let lr = lowrank_params(d, d, r) as f64;
        let pf = (pifa_params(d, d, r) - r) as f64; // exclude index
        let saving = 1.0 - pf / lr;
        assert!((saving - 0.25).abs() < 0.01, "saving={saving}"); // ~25% - 24.2% with idx overhead
    }

    #[test]
    fn flops_ordering() {
        let (m, n, b) = (512usize, 512usize, 8usize);
        for r in [64usize, 128, 256] {
            assert!(pifa_flops(m, n, r, b) < lowrank_flops(m, n, r, b));
        }
        // At r = n/2, PIFA flops < dense flops.
        assert!(pifa_flops(m, n, 256, b) < dense_flops(m, n, b));
    }

    #[test]
    fn density_rank_roundtrip_lowrank() {
        let (m, n) = (256usize, 256usize);
        for rho in [0.2, 0.4, 0.5, 0.8] {
            let r = rank_for_density_lowrank(m, n, rho);
            let got = density_of_lowrank_rank(m, n, r);
            assert!((got - rho).abs() < 0.02, "rho={rho} got={got}");
        }
    }

    #[test]
    fn density_rank_roundtrip_pifa() {
        let (m, n) = (256usize, 256usize);
        for rho in [0.3, 0.5, 0.55, 0.7, 0.9] {
            let r = rank_for_density_pifa(m, n, rho);
            let got = density_of_pifa_rank(m, n, r);
            assert!((got - rho).abs() < 0.02, "rho={rho} got={got}");
        }
    }

    #[test]
    fn pifa_rank_exceeds_lowrank_rank_at_same_density() {
        // The mechanism behind Table 5's W+M+PIFA < W+M.
        let (m, n) = (512usize, 512usize);
        for rho in [0.4, 0.5, 0.6, 0.7] {
            let r_lr = rank_for_density_lowrank(m, n, rho);
            let r_pf = rank_for_density_pifa(m, n, rho);
            assert!(r_pf > r_lr, "rho={rho}: pifa rank {r_pf} <= lowrank rank {r_lr}");
        }
    }

    #[test]
    fn fig3_lu_matches_pifa_count_qr_larger() {
        // Paper Figure 3: LU has the same number of non-trivial parameters
        // as PIFA, QR has more; only PIFA is rectangular.
        let (m, n, r) = (64usize, 48usize, 16usize);
        let lu = lu_structure(m, n, r);
        let qr = qr_structure(m, n, r);
        let pf = pifa_structure(m, n, r);
        assert_eq!(lu.nontrivial, pf.nontrivial);
        assert!(qr.nontrivial > pf.nontrivial);
        assert!(pf.rectangular);
        assert!(!lu.rectangular && !qr.rectangular);
    }
}
