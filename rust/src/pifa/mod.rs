//! Pivoting Factorization (PIFA) — the paper's core contribution (§3).
//!
//! * [`factorize`] — Algorithm 1: pivot-row selection (pivoted QR on `W'^T`,
//!   or LU) + coefficient solve `W_np = C W_p`.
//! * [`layer`] — Algorithm 2: the PIFA inference layer
//!   (`Y_p = W_p X; Y_np = C Y_p; scatter`).
//! * [`costs`] — exact parameter / FLOP accounting behind Figure 1,
//!   Figure 3, and the density↔rank mapping (DESIGN.md §5).

pub mod costs;
pub mod factorize;
pub mod layer;

pub use costs::{
    dense_flops, dense_params, density_of_lowrank_rank, density_of_pifa_rank, lowrank_flops,
    lowrank_params, pifa_flops, pifa_params, rank_for_density_lowrank, rank_for_density_pifa,
};
pub use factorize::{pivoting_factorization, PivotStrategy};
pub use layer::PifaLayer;
