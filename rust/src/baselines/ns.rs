//! MPIFA_NS — non-uniform sparsity (paper Appendix B.2).
//!
//! Two density axes combined multiplicatively:
//!
//! * **Type Density** — attention modules are less sensitive than MLP
//!   modules (ASVD's observation), so attention density is searched in
//!   `{G, G - 0.1}`; MLP density is then solved so the global density
//!   stays `G`.
//! * **Layer Density** — OWL's outlier-weighted layerwise allocation.
//!
//! `Module Density = Type x Layer / Global` (clamped to (0, 1]).

use super::owl::owl_layer_densities;
use crate::compress::mpifa::CompressConfig;
use crate::model::transformer::{ModuleKind, Transformer};

/// Parameters in attention vs MLP modules per block.
fn type_param_split(model: &Transformer) -> (usize, usize) {
    let d = model.cfg.dim;
    let h = model.cfg.ffn_hidden;
    (4 * d * d, 3 * d * h)
}

/// Solve the MLP density so the block-global density equals `global`
/// given the attention density.
fn mlp_density_for(model: &Transformer, global: f64, attn_density: f64) -> f64 {
    let (pa, pm) = type_param_split(model);
    let total = (pa + pm) as f64;
    ((global * total - attn_density * pa as f64) / pm as f64).clamp(0.05, 1.0)
}

/// Build the MPIFA_NS config: type-density split + OWL layer densities.
///
/// `attn_minus` selects the searched attention density: `false` → `G`,
/// `true` → `G - 0.1` (the paper searches both and keeps the better; the
/// benches do that search explicitly).
pub fn mpifa_ns_config(
    model: &Transformer,
    calib: &[Vec<usize>],
    global: f64,
    attn_minus: bool,
) -> CompressConfig {
    let attn_density = if attn_minus { (global - 0.1).max(0.05) } else { global };
    let mlp_density = mlp_density_for(model, global, attn_density);
    let layer_dens = owl_layer_densities(model, calib, global);

    let mut cfg = CompressConfig::mpifa(global);
    for (layer, &ld) in layer_dens.iter().enumerate() {
        for kind in ModuleKind::ALL {
            let type_d = if kind.is_attention() { attn_density } else { mlp_density };
            let module_d = (type_d * ld / global).clamp(0.05, 1.0);
            cfg.module_density.insert((layer, kind), module_d);
        }
    }
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::model::config::ModelConfig;
    use crate::model::transformer::Transformer;

    fn model() -> Transformer {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 64,
            dim: 16,
            n_layers: 2,
            n_heads: 2,
            ffn_hidden: 32,
            max_seq: 16,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        };
        let mut rng = Rng::new(331);
        Transformer::new_random(&cfg, &mut rng)
    }

    fn calib() -> Vec<Vec<usize>> {
        (0..2).map(|i| (0..10).map(|j| (i * 13 + j * 3) % 64).collect()).collect()
    }

    #[test]
    fn global_density_preserved() {
        let m = model();
        for attn_minus in [false, true] {
            let cfg = mpifa_ns_config(&m, &calib(), 0.55, attn_minus);
            // Parameter-weighted mean of module densities == global.
            let d = m.cfg.dim;
            let h = m.cfg.ffn_hidden;
            let mut num = 0.0;
            let mut den = 0.0;
            for ((_, kind), &rho) in cfg.module_density.iter() {
                let params = match kind {
                    ModuleKind::Down => (d * h) as f64,
                    ModuleKind::Gate | ModuleKind::Up => (h * d) as f64,
                    _ => (d * d) as f64,
                };
                num += rho * params;
                den += params;
            }
            let mean = num / den;
            assert!((mean - 0.55).abs() < 0.03, "attn_minus={attn_minus}: mean {mean}");
        }
    }

    #[test]
    fn attn_minus_shifts_budget_to_mlp() {
        let m = model();
        let cfg = mpifa_ns_config(&m, &calib(), 0.5, true);
        let attn_d = cfg.module_density[&(0, ModuleKind::Q)];
        let mlp_d = cfg.module_density[&(0, ModuleKind::Gate)];
        assert!(mlp_d > attn_d, "MLP should get more density: attn {attn_d} mlp {mlp_d}");
    }

    #[test]
    fn every_module_has_density() {
        let m = model();
        let cfg = mpifa_ns_config(&m, &calib(), 0.6, false);
        assert_eq!(cfg.module_density.len(), 2 * 7);
        assert!(cfg.module_density.values().all(|&v| v > 0.0 && v <= 1.0));
    }
}
