//! LLM-Pruner-style structured pruning (Appendix E, Tables 10–12).
//!
//! Removes whole attention heads and whole MLP hidden channels, producing
//! a genuinely *smaller dense* model (tensor shapes shrink — the property
//! that makes structured pruning GPU-friendly at any density, and also
//! what makes it lose more accuracy than finer-grained methods).
//!
//! Importance criteria (activation-weighted weight norms, the
//! retraining-free flavour of LLM-Pruner's Taylor criterion):
//! * channel `c`: `||gate_row_c|| * ||up_row_c|| * ||down_col_c|| * act_c`
//! * head `h`: sum of q/k/v row-block norms + o column-block norm.

use crate::linalg::Mat;
use crate::model::ops;
use crate::model::transformer::Transformer;
use crate::model::LinearRepr;
use anyhow::{ensure, Result};

/// Structured pruning configuration.
#[derive(Clone, Debug)]
pub struct StructuredConfig {
    /// Target density over prunable parameters.
    pub density: f64,
}

fn row_norm(w: &Mat<f32>, i: usize) -> f64 {
    w.row(i).iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt()
}

fn col_norm(w: &Mat<f32>, j: usize) -> f64 {
    (0..w.rows()).map(|i| (w[(i, j)] as f64).powi(2)).sum::<f64>().sqrt()
}

/// Mean |activation| per MLP hidden channel, from calibration.
fn channel_activity(model: &Transformer, calib: &[Vec<usize>], layer: usize) -> Vec<f64> {
    let h = model.cfg.ffn_hidden;
    let mut act = vec![0f64; h];
    let mut count = 0usize;
    for tokens in calib {
        let mut hh = model.embed_tokens(tokens);
        for (li, block) in model.blocks.iter().enumerate() {
            // Advance through attention to tap the true MLP input.
            let mid = {
                let (x_attn, _) = ops::rmsnorm(&hh, &block.attn_norm, model.cfg.norm_eps);
                let q = block.attn.wq.forward(&x_attn);
                let k = block.attn.wk.forward(&x_attn);
                let v = block.attn.wv.forward(&x_attn);
                let (mix, _, _) = crate::model::transformer::attention_mix(
                    &q,
                    &k,
                    &v,
                    &model.rope,
                    model.cfg.n_heads,
                    0,
                    None,
                );
                hh.add_mat(&block.attn.wo.forward(&mix))
            };
            let (x_mlp, _) = ops::rmsnorm(&mid, &block.mlp_norm, model.cfg.norm_eps);
            let g = block.mlp.gate.forward(&x_mlp);
            let u = block.mlp.up.forward(&x_mlp);
            if li == layer {
                for t in 0..g.rows() {
                    for c in 0..h {
                        act[c] += (ops::silu(g[(t, c)]) * u[(t, c)]).abs() as f64;
                    }
                }
                count += g.rows();
            }
            let mut a = g.clone();
            for (av, (gv, uv)) in a
                .as_mut_slice()
                .iter_mut()
                .zip(g.as_slice().iter().zip(u.as_slice().iter()))
            {
                *av = ops::silu(*gv) * *uv;
            }
            hh = mid.add_mat(&block.mlp.down.forward(&a));
        }
    }
    for v in act.iter_mut() {
        *v /= count.max(1) as f64;
    }
    act
}

/// Structured-prune the model: returns a smaller dense model.
pub fn structured_prune_model(
    model: &Transformer,
    calib: &[Vec<usize>],
    cfg: &StructuredConfig,
) -> Result<Transformer> {
    let d = model.cfg.dim;
    let nh = model.cfg.n_heads;
    let hd = d / nh;
    let ffn = model.cfg.ffn_hidden;
    let rho = cfg.density;
    ensure!((0.05..=1.0).contains(&rho), "structured: bad density {rho}");

    // Head/channel budgets: heads are coarse, so round heads first and
    // solve channels to land the global density exactly.
    let keep_heads = ((nh as f64 * rho).round() as usize).clamp(1, nh);
    let pa = 4 * d * d;
    let pm = 3 * d * ffn;
    let target = rho * (pa + pm) as f64;
    let attn_kept = pa as f64 * keep_heads as f64 / nh as f64;
    let keep_ch = (((target - attn_kept) / (3 * d) as f64).round() as usize).clamp(1, ffn);

    let mut out = model.clone();
    out.cfg.n_heads = keep_heads;
    out.cfg.ffn_hidden = keep_ch;
    out.cfg.name = format!("{}-structured{:.0}", model.cfg.name, rho * 100.0);

    for (li, block) in model.blocks.iter().enumerate() {
        let wq = block.attn.wq.to_dense();
        let wk = block.attn.wk.to_dense();
        let wv = block.attn.wv.to_dense();
        let wo = block.attn.wo.to_dense();
        // Head importance.
        let mut head_scores: Vec<(f64, usize)> = (0..nh)
            .map(|hi| {
                let mut s = 0.0;
                for r in hi * hd..(hi + 1) * hd {
                    s += row_norm(&wq, r) + row_norm(&wk, r) + row_norm(&wv, r);
                    s += col_norm(&wo, r);
                }
                (s, hi)
            })
            .collect();
        head_scores.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut kept: Vec<usize> = head_scores[..keep_heads].iter().map(|&(_, h)| h).collect();
        kept.sort_unstable();
        let rows: Vec<usize> = kept.iter().flat_map(|&h| h * hd..(h + 1) * hd).collect();

        let b = &mut out.blocks[li];
        b.attn.wq = LinearRepr::Dense(wq.select_rows(&rows));
        b.attn.wk = LinearRepr::Dense(wk.select_rows(&rows));
        b.attn.wv = LinearRepr::Dense(wv.select_rows(&rows));
        b.attn.wo = LinearRepr::Dense(wo.select_cols(&rows));

        // MLP channel importance.
        let act = channel_activity(model, calib, li);
        let wg = block.mlp.gate.to_dense();
        let wu = block.mlp.up.to_dense();
        let wd = block.mlp.down.to_dense();
        let mut ch_scores: Vec<(f64, usize)> = (0..ffn)
            .map(|c| {
                let s = row_norm(&wg, c) * row_norm(&wu, c) * col_norm(&wd, c) * (act[c] + 1e-9);
                (s, c)
            })
            .collect();
        ch_scores.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut keep_cols: Vec<usize> = ch_scores[..keep_ch].iter().map(|&(_, c)| c).collect();
        keep_cols.sort_unstable();
        b.mlp.gate = LinearRepr::Dense(wg.select_rows(&keep_cols));
        b.mlp.up = LinearRepr::Dense(wu.select_rows(&keep_cols));
        b.mlp.down = LinearRepr::Dense(wd.select_cols(&keep_cols));
    }
    Ok(out)
}

/// Structured density actually achieved (for reporting).
pub fn achieved_density(pruned: &Transformer, original: &Transformer) -> f64 {
    pruned.prunable_params() as f64 / original.cfg.prunable_param_count() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::model::config::ModelConfig;

    fn model() -> Transformer {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 64,
            dim: 32,
            n_layers: 2,
            n_heads: 4,
            ffn_hidden: 48,
            max_seq: 16,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        };
        let mut rng = Rng::new(341);
        Transformer::new_random(&cfg, &mut rng)
    }

    fn calib() -> Vec<Vec<usize>> {
        (0..2).map(|i| (0..10).map(|j| (i * 17 + j * 3) % 64).collect()).collect()
    }

    #[test]
    fn density_is_hit() {
        let m = model();
        for rho in [0.55, 0.7] {
            let p = structured_prune_model(&m, &calib(), &StructuredConfig { density: rho }).unwrap();
            let got = achieved_density(&p, &m);
            assert!((got - rho).abs() < 0.06, "target {rho} got {got}");
        }
    }

    #[test]
    fn pruned_model_still_runs() {
        let m = model();
        let p = structured_prune_model(&m, &calib(), &StructuredConfig { density: 0.55 }).unwrap();
        let logits = p.forward(&[1, 5, 9, 2], None);
        assert_eq!(logits.shape(), (4, 64));
        assert!(logits.all_finite());
    }

    #[test]
    fn decode_path_works_after_head_pruning() {
        let m = model();
        let p = structured_prune_model(&m, &calib(), &StructuredConfig { density: 0.55 }).unwrap();
        // Full-forward vs KV-decode parity on the pruned model.
        let tokens = [3usize, 7, 11, 2];
        let full = p.forward(&tokens, None);
        let mut cache = crate::model::transformer::KvCache::new(&p.cfg);
        let mut last = Mat::zeros(1, 64);
        for &t in &tokens {
            last = p.decode_step(t, &mut cache);
        }
        let ti = tokens.len() - 1;
        for j in 0..64 {
            assert!(
                (full[(ti, j)] - last[(0, j)]).abs() < 1e-3,
                "pruned decode mismatch at {j}"
            );
        }
    }

    #[test]
    fn keeps_important_channels() {
        // Boost one channel's weights hugely; it must survive.
        let mut m = model();
        if let LinearRepr::Dense(w) = &mut m.blocks[0].mlp.gate {
            for j in 0..w.cols() {
                w[(7, j)] *= 50.0;
            }
        }
        if let LinearRepr::Dense(w) = &mut m.blocks[0].mlp.up {
            for j in 0..w.cols() {
                w[(7, j)] *= 50.0;
            }
        }
        let p = structured_prune_model(&m, &calib(), &StructuredConfig { density: 0.5 }).unwrap();
        // Channel 7's gate row (large values) must appear among kept rows.
        let wg = p.blocks[0].mlp.gate.to_dense();
        let max_row_norm = (0..wg.rows()).map(|i| row_norm(&wg, i)).fold(0.0, f64::max);
        let orig7 = row_norm(&m.blocks[0].mlp.gate.to_dense(), 7);
        assert!(
            (max_row_norm - orig7).abs() / orig7 < 1e-6,
            "boosted channel was pruned"
        );
    }
}
