//! One-shot 2:4 semi-structured pruning baselines (Tables 3/4):
//! Magnitude (Zhu & Gupta), Wanda (Sun et al.), RIA (Zhang et al.).
//!
//! All three share the 2:4 mask selection (`crate::sparse24`); they differ
//! only in the per-weight importance score:
//!
//! * Magnitude: `|W_ij|`
//! * Wanda:     `|W_ij| * ||X_j||_2`
//! * RIA:       `(|W_ij| / Σ_i |W_ij| + |W_ij| / Σ_j |W_ij|) * ||X_j||_2^a`

use crate::linalg::Mat;
use crate::model::ops;
use crate::model::transformer::{attention_mix, ModuleKind, Transformer};
use crate::model::LinearRepr;
use crate::sparse24::{prune_mask_24, Sparse24Mat};
use std::collections::HashMap;

/// Importance score flavour.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Score24 {
    Magnitude,
    Wanda,
    /// RIA with activation exponent `a` (paper uses 0.5).
    Ria { a: f64 },
}

/// Per-module squared input-column norms collected from the dense flow.
type ColNorms = HashMap<(usize, ModuleKind), Vec<f64>>;

/// Run the dense model over calibration windows, accumulating per-module
/// input activation column norms `||X_j||_2^2`.
fn collect_col_norms(model: &Transformer, calib: &[Vec<usize>]) -> ColNorms {
    let mut norms: ColNorms = HashMap::new();
    let eps = model.cfg.norm_eps;
    let n_heads = model.cfg.n_heads;
    for tokens in calib {
        let mut h = model.embed_tokens(tokens);
        for (li, block) in model.blocks.iter().enumerate() {
            let (x_attn, _) = ops::rmsnorm(&h, &block.attn_norm, eps);
            add_sq(&mut norms, (li, ModuleKind::Q), &x_attn);
            add_sq(&mut norms, (li, ModuleKind::K), &x_attn);
            add_sq(&mut norms, (li, ModuleKind::V), &x_attn);
            let q = block.attn.wq.forward(&x_attn);
            let k = block.attn.wk.forward(&x_attn);
            let v = block.attn.wv.forward(&x_attn);
            let (mix, _, _) = attention_mix(&q, &k, &v, &model.rope, n_heads, 0, None);
            add_sq(&mut norms, (li, ModuleKind::O), &mix);
            h = h.add_mat(&block.attn.wo.forward(&mix));
            let (x_mlp, _) = ops::rmsnorm(&h, &block.mlp_norm, eps);
            add_sq(&mut norms, (li, ModuleKind::Gate), &x_mlp);
            add_sq(&mut norms, (li, ModuleKind::Up), &x_mlp);
            let g = block.mlp.gate.forward(&x_mlp);
            let u = block.mlp.up.forward(&x_mlp);
            let mut a = g.clone();
            for (av, (gv, uv)) in a
                .as_mut_slice()
                .iter_mut()
                .zip(g.as_slice().iter().zip(u.as_slice().iter()))
            {
                *av = ops::silu(*gv) * *uv;
            }
            add_sq(&mut norms, (li, ModuleKind::Down), &a);
            h = h.add_mat(&block.mlp.down.forward(&a));
        }
    }
    norms
}

fn add_sq(norms: &mut ColNorms, key: (usize, ModuleKind), x: &Mat<f32>) {
    let e = norms.entry(key).or_insert_with(|| vec![0f64; x.cols()]);
    for i in 0..x.rows() {
        for (j, v) in x.row(i).iter().enumerate() {
            e[j] += (*v as f64) * (*v as f64);
        }
    }
}

/// Importance scores for one weight matrix.
fn scores_for(w: &Mat<f32>, col_sq: &[f64], score: Score24) -> Mat<f32> {
    let (m, n) = w.shape();
    match score {
        Score24::Magnitude => w.map(|v| v.abs()),
        Score24::Wanda => {
            let mut s = Mat::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    s[(i, j)] = w[(i, j)].abs() * (col_sq[j].sqrt() as f32);
                }
            }
            s
        }
        Score24::Ria { a } => {
            let mut row_sum = vec![0f64; m];
            let mut col_sum = vec![0f64; n];
            for i in 0..m {
                for j in 0..n {
                    let v = w[(i, j)].abs() as f64;
                    row_sum[i] += v;
                    col_sum[j] += v;
                }
            }
            let mut s = Mat::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    let v = w[(i, j)].abs() as f64;
                    let ri = v / row_sum[i].max(1e-30) + v / col_sum[j].max(1e-30);
                    s[(i, j)] = (ri * col_sq[j].sqrt().powf(a)) as f32;
                }
            }
            s
        }
    }
}

/// Prune every prunable linear of the model to 2:4 with the given score.
pub fn compress_model_24(model: &Transformer, calib: &[Vec<usize>], score: Score24) -> Transformer {
    let norms = if matches!(score, Score24::Magnitude) {
        ColNorms::new() // magnitude needs no activations
    } else {
        collect_col_norms(model, calib)
    };
    let mut out = model.clone();
    for li in 0..model.cfg.n_layers {
        for kind in ModuleKind::ALL {
            let w = model.module(li, kind).to_dense();
            let ones = vec![1.0f64; w.cols()];
            let col_sq = norms.get(&(li, kind)).map(|v| v.as_slice()).unwrap_or(&ones);
            let s = scores_for(&w, col_sq, score);
            let mask = prune_mask_24(&s);
            *out.module_mut(li, kind) = LinearRepr::Sparse24(Sparse24Mat::pack(&w, &mask));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::model::config::ModelConfig;

    fn model() -> Transformer {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 64,
            dim: 16,
            n_layers: 2,
            n_heads: 2,
            ffn_hidden: 24,
            max_seq: 16,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        };
        let mut rng = Rng::new(311);
        Transformer::new_random(&cfg, &mut rng)
    }

    fn calib() -> Vec<Vec<usize>> {
        (0..4).map(|i| (0..12).map(|j| (i * 7 + j * 3) % 64).collect()).collect()
    }

    #[test]
    fn all_modules_become_sparse24_at_half_density() {
        let m = model();
        for score in [Score24::Magnitude, Score24::Wanda, Score24::Ria { a: 0.5 }] {
            let c = compress_model_24(&m, &calib(), score);
            for li in 0..2 {
                for kind in ModuleKind::ALL {
                    assert_eq!(c.module(li, kind).kind_name(), "sparse24", "{score:?}");
                }
            }
            let d = c.density();
            assert!((d - 0.5).abs() < 1e-9, "{score:?} density {d}");
        }
    }

    #[test]
    fn wanda_and_magnitude_choose_differently() {
        // With strongly anisotropic activations the masks must differ.
        let m = model();
        let a = compress_model_24(&m, &calib(), Score24::Magnitude);
        let b = compress_model_24(&m, &calib(), Score24::Wanda);
        let wa = a.module(0, ModuleKind::Q).to_dense();
        let wb = b.module(0, ModuleKind::Q).to_dense();
        let mut diff = 0;
        for (x, y) in wa.as_slice().iter().zip(wb.as_slice()) {
            if (*x == 0.0) != (*y == 0.0) {
                diff += 1;
            }
        }
        assert!(diff > 0, "Wanda mask identical to magnitude mask");
    }

    #[test]
    fn wanda_beats_magnitude_on_output_error() {
        // The defining Wanda property: lower ||W X - W_masked X||_F on the
        // calibration distribution.
        let m = model();
        let cal = calib();
        let mag = compress_model_24(&m, &cal, Score24::Magnitude);
        let wan = compress_model_24(&m, &cal, Score24::Wanda);
        // Compare on the first-layer Q module with real activations.
        let h = m.embed_tokens(&cal[0]);
        let (x, _) = crate::model::ops::rmsnorm(&h, &m.blocks[0].attn_norm, 1e-5);
        let w_full = m.module(0, ModuleKind::Q).to_dense();
        let y_ref = crate::linalg::matmul_nt(&x, &w_full);
        let err = |c: &Transformer| {
            let y = c.module(0, ModuleKind::Q).forward(&x);
            y.fro_dist(&y_ref)
        };
        let e_mag = err(&mag);
        let e_wan = err(&wan);
        assert!(e_wan <= e_mag * 1.001, "Wanda ({e_wan}) worse than magnitude ({e_mag})");
    }

    #[test]
    fn ria_scores_finite_and_positive() {
        let mut rng = Rng::new(312);
        let w: Mat<f32> = Mat::randn(8, 16, &mut rng);
        let col_sq = vec![2.0f64; 16];
        let s = scores_for(&w, &col_sq, Score24::Ria { a: 0.5 });
        assert!(s.all_finite());
        assert!(s.as_slice().iter().all(|&v| v >= 0.0));
    }
}
