//! OWL (Outlier Weighed Layerwise sparsity, Yin et al.) — layer-wise
//! density allocation from activation-outlier distribution.
//!
//! OWL's observation: layers whose activations carry more outliers are
//! more sensitive to pruning and should keep more parameters. We compute,
//! per layer, the fraction of block-input activations whose magnitude
//! exceeds `OUTLIER_M x` the layer mean, then tilt per-layer densities
//! toward outlier-heavy layers while keeping the global density fixed.

use crate::model::ops;
use crate::model::transformer::Transformer;

/// Outlier threshold multiplier (OWL uses M in [3, 10]; 5 is its default).
const OUTLIER_M: f64 = 5.0;
/// Maximum deviation of a layer's density from the global target (OWL's
/// lambda; keeps allocations sane at extreme densities).
const MAX_SHIFT: f64 = 0.08;

/// Per-layer outlier ratios of block-input activations.
pub fn layer_outlier_ratios(model: &Transformer, calib: &[Vec<usize>]) -> Vec<f64> {
    let l = model.cfg.n_layers;
    let mut ratios = vec![0f64; l];
    let mut counts = vec![0usize; l];
    for tokens in calib {
        let mut h = model.embed_tokens(tokens);
        for (li, block) in model.blocks.iter().enumerate() {
            // Outlier statistic on the block input (pre-norm), like OWL.
            let abs: Vec<f64> = h.as_slice().iter().map(|v| v.abs() as f64).collect();
            let mean = abs.iter().sum::<f64>() / abs.len().max(1) as f64;
            let outliers = abs.iter().filter(|&&v| v > OUTLIER_M * mean).count();
            ratios[li] += outliers as f64 / abs.len().max(1) as f64;
            counts[li] += 1;
            h = crate::model::transformer::block_forward(
                block,
                &h,
                &model.rope,
                model.cfg.n_heads,
                model.cfg.norm_eps,
                None,
            );
            let _ = ops::silu(0.0); // keep ops linked for doc example
        }
    }
    for (r, c) in ratios.iter_mut().zip(counts.iter()) {
        *r /= (*c).max(1) as f64;
    }
    ratios
}

/// OWL layer densities: tilt `global` by normalized outlier ratio, clamp
/// to `global ± MAX_SHIFT`, then renormalize so the parameter-weighted
/// mean density equals `global` exactly.
pub fn owl_layer_densities(model: &Transformer, calib: &[Vec<usize>], global: f64) -> Vec<f64> {
    let ratios = layer_outlier_ratios(model, calib);
    let l = ratios.len();
    let mean_r = ratios.iter().sum::<f64>() / l.max(1) as f64;
    let mut dens: Vec<f64> = ratios
        .iter()
        .map(|&r| {
            let tilt = if mean_r > 1e-12 { (r - mean_r) / mean_r } else { 0.0 };
            (global + MAX_SHIFT * tilt.clamp(-1.0, 1.0)).clamp(0.05, 1.0)
        })
        .collect();
    // Renormalize to preserve the global density (all layers have equal
    // prunable parameter counts in our models).
    let mean_d = dens.iter().sum::<f64>() / l.max(1) as f64;
    if mean_d > 1e-12 {
        let scale = global / mean_d;
        for d in dens.iter_mut() {
            *d = (*d * scale).clamp(0.05, 1.0);
        }
    }
    dens
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::model::config::ModelConfig;

    fn model() -> Transformer {
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 64,
            dim: 16,
            n_layers: 3,
            n_heads: 2,
            ffn_hidden: 24,
            max_seq: 16,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        };
        let mut rng = Rng::new(321);
        Transformer::new_random(&cfg, &mut rng)
    }

    fn calib() -> Vec<Vec<usize>> {
        (0..3).map(|i| (0..10).map(|j| (i * 11 + j * 5) % 64).collect()).collect()
    }

    #[test]
    fn ratios_have_layer_count() {
        let m = model();
        let r = layer_outlier_ratios(&m, &calib());
        assert_eq!(r.len(), 3);
        assert!(r.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn densities_preserve_global_mean() {
        let m = model();
        for global in [0.4, 0.55, 0.7] {
            let d = owl_layer_densities(&m, &calib(), global);
            let mean = d.iter().sum::<f64>() / d.len() as f64;
            assert!((mean - global).abs() < 0.02, "global {global} -> mean {mean}");
            assert!(d.iter().all(|&v| v > 0.0 && v <= 1.0));
        }
    }

    #[test]
    fn densities_bounded_shift() {
        let m = model();
        let d = owl_layer_densities(&m, &calib(), 0.5);
        for &v in &d {
            assert!((v - 0.5).abs() <= MAX_SHIFT + 0.05, "density {v} shifted too far");
        }
    }
}
