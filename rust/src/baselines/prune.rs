//! Low-rank pruning algorithms — the prune slot of the MPIFA walk.
//!
//! All take `(W, accumulated X X^T, rank)` and return `(U, V^T)`:
//!
//! * [`PruneAlgo::VanillaSvd`] — plain truncated SVD of `W`.
//! * [`PruneAlgo::Asvd`] — activation-aware SVD (Yuan et al. 2023):
//!   scale input channels by `rms_j^alpha` before truncating, so channels
//!   that carry large activations keep more fidelity.
//! * [`PruneAlgo::SvdLlm`] — truncation-aware data whitening
//!   (`crate::compress::whiten`).
//! * [`PruneAlgo::Espace`] — ESPACE's activation-space projections
//!   (Sakr & Khailany): `W x ≈ (W P)(P^T x)` with `P` chosen per variant.
//!   The NL-MSE variants are excluded as in the paper (Appendix G: they
//!   require backprop).

use crate::compress::recon::DualFlowAccum;
use crate::compress::whiten::svdllm_prune;
use crate::linalg::{self, Mat};
use anyhow::Result;

/// ESPACE projection variants (paper Appendix G / Table 15).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EspaceVariant {
    /// Eigenvectors of the raw activation Gram `X X^T`.
    Mse,
    /// Eigenvectors of the channel-normalized Gram.
    MseNorm,
    /// Output-aware: weights the Gram by `W^T W` before the eigenbasis.
    GoMse,
    /// Output-aware + channel normalization.
    GoMseNorm,
}

/// Which low-rank pruning algorithm produces the initial `U V^T`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PruneAlgo {
    SvdLlm,
    VanillaSvd,
    Asvd { alpha: f64 },
    Espace(EspaceVariant),
}

/// Run the selected pruning algorithm.
pub fn prune_low_rank(
    algo: &PruneAlgo,
    w: &Mat<f64>,
    accum: &DualFlowAccum,
    r: usize,
) -> Result<(Mat<f64>, Mat<f64>)> {
    match algo {
        PruneAlgo::SvdLlm => svdllm_prune(w, &accum.xxt, r),
        PruneAlgo::VanillaSvd => Ok(linalg::svd(w).truncate(r)),
        PruneAlgo::Asvd { alpha } => asvd_prune(w, accum, r, *alpha),
        PruneAlgo::Espace(v) => espace_prune(w, accum, r, *v),
    }
}

/// Channel RMS magnitudes from the accumulated Gram diagonal.
fn channel_rms(accum: &DualFlowAccum) -> Vec<f64> {
    let n = accum.xxt.rows();
    let t = accum.tokens.max(1) as f64;
    (0..n).map(|j| (accum.xxt[(j, j)] / t).sqrt().max(1e-12)).collect()
}

/// ASVD: truncate `SVD(W D)` with `D = diag(rms^alpha)`, un-scale `V^T`.
fn asvd_prune(w: &Mat<f64>, accum: &DualFlowAccum, r: usize, alpha: f64) -> Result<(Mat<f64>, Mat<f64>)> {
    let n = w.cols();
    let d: Vec<f64> = channel_rms(accum).iter().map(|v| v.powf(alpha)).collect();
    let mut wd = w.clone();
    for i in 0..w.rows() {
        let row = wd.row_mut(i);
        for j in 0..n {
            row[j] *= d[j];
        }
    }
    let (u, mut vt) = linalg::svd(&wd).truncate(r);
    for i in 0..vt.rows() {
        let row = vt.row_mut(i);
        for j in 0..n {
            row[j] /= d[j];
        }
    }
    Ok((u, vt))
}

/// ESPACE: choose an orthonormal projection `P (n x r)` of the activation
/// space, then `U = W P`, `V^T = P^T` (optionally conjugated by the
/// channel scaling for the NORM variants).
fn espace_prune(
    w: &Mat<f64>,
    accum: &DualFlowAccum,
    r: usize,
    variant: EspaceVariant,
) -> Result<(Mat<f64>, Mat<f64>)> {
    let n = w.cols();
    let normalize = matches!(variant, EspaceVariant::MseNorm | EspaceVariant::GoMseNorm);
    let output_aware = matches!(variant, EspaceVariant::GoMse | EspaceVariant::GoMseNorm);

    // Optionally conjugate the Gram by D^{-1/2} (channel normalization).
    let rms = channel_rms(accum);
    let scale: Vec<f64> = if normalize { rms.iter().map(|v| 1.0 / v.sqrt()).collect() } else { vec![1.0; n] };
    let mut g = accum.xxt.clone();
    for i in 0..n {
        for j in 0..n {
            g[(i, j)] *= scale[i] * scale[j];
        }
    }

    // Output-aware weighting: symmetrized 0.5 (G B + B G) with B = W^T W
    // (in the scaled space). This folds the layer's output sensitivity
    // into the projection choice — the "GO" step.
    let m_sym = if output_aware {
        // W in the scaled input space: W D^{1/2} equivalent is W ./ scale
        // (since x_scaled = D^{1/2} x and we project x_scaled).
        let mut ws = w.clone();
        for i in 0..w.rows() {
            let row = ws.row_mut(i);
            for j in 0..n {
                row[j] /= scale[j].max(1e-300);
            }
        }
        let b = linalg::matmul_tn(&ws, &ws); // n x n
        let gb = linalg::matmul(&g, &b);
        let bg = linalg::matmul(&b, &g);
        let mut m = gb.add_mat(&bg);
        m.scale_inplace(0.5);
        m
    } else {
        g
    };

    // Top-r eigenvectors via SVD of the symmetric matrix.
    let f = linalg::svd(&m_sym);
    let mut p = Mat::zeros(n, r);
    for i in 0..n {
        for j in 0..r {
            p[(i, j)] = f.u[(i, j)];
        }
    }
    // Orthonormality safeguard (SVD of a symmetric PSD matrix gives an
    // orthonormal U, but the GO symmetrization can be indefinite; re-
    // orthonormalize via pivoted QR of P).
    let qr = linalg::qr_column_pivot(&p);
    let mut q = Mat::eye(n);
    qr.apply_qt(&mut q);
    let q = q.transpose();
    let mut p_ortho = Mat::zeros(n, r);
    for i in 0..n {
        for j in 0..r {
            p_ortho[(i, j)] = q[(i, j)];
        }
    }

    // Projection in the (possibly scaled) space:
    // W x = (W D^{-1/2}) (D^{1/2} x) ≈ (W D^{-1/2} P)(P^T D^{1/2} x).
    let u = {
        let mut ws = w.clone();
        for i in 0..w.rows() {
            let row = ws.row_mut(i);
            for j in 0..n {
                row[j] /= scale[j].max(1e-300);
            }
        }
        linalg::matmul(&ws, &p_ortho)
    };
    let mut vt = p_ortho.transpose(); // r x n
    for i in 0..r {
        let row = vt.row_mut(i);
        for j in 0..n {
            row[j] *= scale[j];
        }
    }
    Ok((u, vt))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_nt, Rng};

    fn setup(m: usize, n: usize, t: usize, seed: u64) -> (Mat<f64>, DualFlowAccum) {
        let mut rng = Rng::new(seed);
        let w: Mat<f64> = Mat::randn(m, n, &mut rng);
        // Anisotropic activations.
        let mut x: Mat<f64> = Mat::randn(n, t, &mut rng);
        for j in 0..n {
            let s = 1.0 + 4.0 * (j as f64 / n as f64);
            for c in 0..t {
                x[(j, c)] *= s;
            }
        }
        let mut acc = DualFlowAccum::new(n);
        acc.add_sample(&x, &x);
        (w, acc)
    }

    fn weighted_err(w: &Mat<f64>, u: &Mat<f64>, vt: &Mat<f64>, acc: &DualFlowAccum) -> f64 {
        crate::compress::whiten::weighted_error(w, u, vt, &acc.xxt)
    }

    #[test]
    fn all_algorithms_produce_right_shapes() {
        let (w, acc) = setup(18, 14, 60, 301);
        for algo in [
            PruneAlgo::SvdLlm,
            PruneAlgo::VanillaSvd,
            PruneAlgo::Asvd { alpha: 0.5 },
            PruneAlgo::Espace(EspaceVariant::Mse),
            PruneAlgo::Espace(EspaceVariant::MseNorm),
            PruneAlgo::Espace(EspaceVariant::GoMse),
            PruneAlgo::Espace(EspaceVariant::GoMseNorm),
        ] {
            let (u, vt) = prune_low_rank(&algo, &w, &acc, 5).unwrap();
            assert_eq!(u.shape(), (18, 5), "{algo:?}");
            assert_eq!(vt.shape(), (5, 14), "{algo:?}");
            assert!(u.all_finite() && vt.all_finite(), "{algo:?}");
        }
    }

    #[test]
    fn asvd_beats_vanilla_on_weighted_error() {
        let (w, acc) = setup(20, 16, 100, 302);
        let r = 5;
        let (u_v, vt_v) = prune_low_rank(&PruneAlgo::VanillaSvd, &w, &acc, r).unwrap();
        let (u_a, vt_a) = prune_low_rank(&PruneAlgo::Asvd { alpha: 0.5 }, &w, &acc, r).unwrap();
        let e_v = weighted_err(&w, &u_v, &vt_v, &acc);
        let e_a = weighted_err(&w, &u_a, &vt_a, &acc);
        assert!(e_a < e_v, "ASVD ({e_a}) should beat vanilla ({e_v}) on activation error");
    }

    #[test]
    fn svdllm_beats_asvd_on_weighted_error() {
        // Whitening is the optimal activation-weighted truncation.
        let (w, acc) = setup(20, 16, 100, 303);
        let r = 5;
        let (u_a, vt_a) = prune_low_rank(&PruneAlgo::Asvd { alpha: 0.5 }, &w, &acc, r).unwrap();
        let (u_s, vt_s) = prune_low_rank(&PruneAlgo::SvdLlm, &w, &acc, r).unwrap();
        let e_a = weighted_err(&w, &u_a, &vt_a, &acc);
        let e_s = weighted_err(&w, &u_s, &vt_s, &acc);
        assert!(e_s <= e_a * 1.0001, "SVD-LLM ({e_s}) should beat ASVD ({e_a})");
    }

    #[test]
    fn espace_go_beats_plain_mse() {
        // The Table 15 ordering: output-aware projections beat pure
        // activation-MSE projections on the *output* error.
        let (w, acc) = setup(24, 18, 120, 304);
        let r = 6;
        let (u_m, vt_m) = prune_low_rank(&PruneAlgo::Espace(EspaceVariant::Mse), &w, &acc, r).unwrap();
        let (u_g, vt_g) =
            prune_low_rank(&PruneAlgo::Espace(EspaceVariant::GoMse), &w, &acc, r).unwrap();
        let e_m = weighted_err(&w, &u_m, &vt_m, &acc);
        let e_g = weighted_err(&w, &u_g, &vt_g, &acc);
        assert!(e_g <= e_m * 1.0001, "GO-MSE ({e_g}) should beat MSE ({e_m})");
    }

    #[test]
    fn espace_projection_is_exact_on_projected_inputs() {
        // For inputs already inside span(P), the factorization is exact.
        let (w, acc) = setup(12, 10, 80, 305);
        let (u, vt) = prune_low_rank(&PruneAlgo::Espace(EspaceVariant::Mse), &w, &acc, 10).unwrap();
        // Full rank r = n: exact reconstruction.
        let rec = matmul(&u, &vt);
        assert!(rec.rel_fro_err(&w) < 1e-8, "err {}", rec.rel_fro_err(&w));
    }

    #[test]
    fn full_rank_recovery_all_algos() {
        let (w, acc) = setup(10, 10, 50, 306);
        for algo in [
            PruneAlgo::SvdLlm,
            PruneAlgo::VanillaSvd,
            PruneAlgo::Asvd { alpha: 0.5 },
        ] {
            let (u, vt) = prune_low_rank(&algo, &w, &acc, 10).unwrap();
            let rec = matmul(&u, &vt);
            assert!(rec.rel_fro_err(&w) < 1e-7, "{algo:?}: {}", rec.rel_fro_err(&w));
        }
    }

    #[test]
    fn channel_rms_matches_direct() {
        let mut rng = Rng::new(307);
        let x: Mat<f64> = Mat::randn(6, 40, &mut rng);
        let mut acc = DualFlowAccum::new(6);
        acc.add_sample(&x, &x);
        let rms = channel_rms(&acc);
        let xxt = matmul_nt(&x, &x);
        for j in 0..6 {
            let direct = (xxt[(j, j)] / 40.0).sqrt();
            assert!((rms[j] - direct).abs() < 1e-10);
        }
    }
}
