//! Every comparator in the paper's evaluation.
//!
//! * [`prune`] — low-rank pruning algorithms that plug into the MPIFA
//!   walk's prune slot: vanilla SVD, ASVD (activation-aware), SVD-LLM
//!   (re-exported), and the four ESPACE projection variants (Table 15).
//! * [`semistructured`] — 2:4 one-shot pruning: Magnitude, Wanda, RIA
//!   (Tables 3/4).
//! * [`structured`] — LLM-Pruner-style structured channel/head pruning
//!   (Tables 10–12).
//! * [`owl`] — OWL outlier-weighted layer-wise density allocation.
//! * [`ns`] — MPIFA_NS non-uniform density construction (Appendix B.2).

pub mod ns;
pub mod owl;
pub mod prune;
pub mod semistructured;
pub mod structured;

pub use ns::mpifa_ns_config;
pub use owl::owl_layer_densities;
pub use prune::{prune_low_rank, EspaceVariant, PruneAlgo};
pub use semistructured::{compress_model_24, Score24};
pub use structured::{structured_prune_model, StructuredConfig};
