//! Binds a Rust [`Transformer`] checkpoint to an AOT artifact and drives
//! prefill / decode through PJRT.

use super::kernels::gather;
use super::kvpool::{BlockPool, KvPoolConfig, KvPoolStats, SeqKv};
use super::loader::{literal_f32, literal_i32, Engine};
use super::manifest::{ArtifactKind, TensorSpec};
use crate::model::transformer::{ModuleKind, Transformer};
use crate::model::LinearRepr;
use anyhow::{bail, Context, Result};

/// Borrow a literal's f32 host data without copying. With the vendored
/// host-side stub this is a zero-copy view; the borrow is isolated in
/// this one helper so a swap to real device-resident bindings only has
/// to reintroduce a `to_vec` readback here.
pub(crate) fn literal_f32_view(lit: &xla::Literal) -> Result<&[f32]> {
    <f32 as xla::NativeType>::extract(lit).context("borrowing f32 literal data")
}

fn kind_of(tag: &str) -> Result<ModuleKind> {
    Ok(match tag {
        "q" => ModuleKind::Q,
        "k" => ModuleKind::K,
        "v" => ModuleKind::V,
        "o" => ModuleKind::O,
        "gate" => ModuleKind::Gate,
        "up" => ModuleKind::Up,
        "down" => ModuleKind::Down,
        other => bail!("unknown module tag {other}"),
    })
}

/// Convert a checkpointed model into the artifact's canonical parameter
/// literals. Shapes are validated against the manifest — a mismatch means
/// the model was compressed with a different density/flavour than the
/// artifact was lowered for.
pub fn weights_to_literals(model: &Transformer, params: &[TensorSpec]) -> Result<Vec<xla::Literal>> {
    let mut out = Vec::with_capacity(params.len());
    for spec in params {
        let lit = tensor_for(model, spec)
            .with_context(|| format!("building literal for param '{}'", spec.name))?;
        out.push(lit);
    }
    Ok(out)
}

fn mat_literal(m: &crate::linalg::Mat<f32>, spec: &TensorSpec) -> Result<xla::Literal> {
    let want: Vec<usize> = spec.dims.clone();
    let got = vec![m.rows(), m.cols()];
    if want != got {
        bail!("shape mismatch: artifact wants {want:?}, model has {got:?}");
    }
    literal_f32(m.as_slice(), &spec.dims)
}

fn vec_literal(v: &[f32], spec: &TensorSpec) -> Result<xla::Literal> {
    if spec.dims != vec![v.len()] {
        bail!("shape mismatch: artifact wants {:?}, model has [{}]", spec.dims, v.len());
    }
    literal_f32(v, &spec.dims)
}

fn tensor_for(model: &Transformer, spec: &TensorSpec) -> Result<xla::Literal> {
    let name = spec.name.as_str();
    match name {
        "embed" => return mat_literal(&model.embed, spec),
        "head" => return mat_literal(&model.head, spec),
        "final_norm" => return vec_literal(&model.final_norm, spec),
        _ => {}
    }
    // l{i}.{field}[.{part}]
    let rest = name.strip_prefix('l').context("param name must start with l")?;
    let (layer_s, tail) = rest.split_once('.').context("missing layer dot")?;
    let layer: usize = layer_s.parse().context("bad layer index")?;
    if layer >= model.blocks.len() {
        bail!("layer {layer} out of range");
    }
    match tail {
        "attn_norm" => return vec_literal(&model.blocks[layer].attn_norm, spec),
        "mlp_norm" => return vec_literal(&model.blocks[layer].mlp_norm, spec),
        _ => {}
    }
    let (mod_tag, part) = tail.split_once('.').context("missing module part")?;
    let kind = kind_of(mod_tag)?;
    let repr = model.module(layer, kind);
    match (repr, part) {
        (LinearRepr::Dense(w), "w") => mat_literal(w, spec),
        (LinearRepr::LowRank { u, .. }, "u") => mat_literal(u, spec),
        (LinearRepr::LowRank { vt, .. }, "vt") => mat_literal(vt, spec),
        (LinearRepr::Pifa(p), "w_p") => mat_literal(&p.w_p, spec),
        (LinearRepr::Pifa(p), "c") => mat_literal(&p.c, spec),
        (LinearRepr::Pifa(p), "inv_perm") => {
            if spec.dims != vec![p.m] {
                bail!("inv_perm shape mismatch");
            }
            // Output channel i reads concat([pivots, non_pivots]) position
            // inv[i].
            let mut inv = vec![0i32; p.m];
            for (pos, &ch) in p.pivots.iter().chain(p.non_pivots.iter()).enumerate() {
                inv[ch] = pos as i32;
            }
            literal_i32(&inv, &spec.dims)
        }
        (r, p) => bail!(
            "model module l{layer}.{} is '{}' but artifact wants part '{p}'",
            mod_tag,
            r.kind_name()
        ),
    }
}

/// Drives one (model, artifact-pair) through PJRT: batch prefill + decode.
pub struct ModelRunner {
    pub prefill_name: String,
    pub decode_name: String,
    weights: Vec<xla::Literal>,
    pub batch: usize,
    pub prefill_seq: usize,
    pub max_seq: usize,
    pub vocab: usize,
    pub layers: usize,
    pub dim: usize,
}

/// Opaque KV-cache state held between decode steps (host literals).
pub struct KvState {
    pub k: xla::Literal,
    pub v: xla::Literal,
    pub pos: usize,
}

impl ModelRunner {
    /// Bind `model` to the given prefill/decode artifact pair.
    pub fn new(
        engine: &mut Engine,
        model: &Transformer,
        prefill_name: &str,
        decode_name: &str,
    ) -> Result<Self> {
        let dspec = engine.manifest.get(decode_name)?.clone();
        let (batch, max_seq, vocab, layers, dim) = match &dspec.kind {
            ArtifactKind::Model { batch, max_seq, vocab, layers, dim, .. } => {
                (*batch, *max_seq, *vocab, *layers, *dim)
            }
            _ => bail!("{decode_name} is not a model artifact"),
        };
        let pspec = engine.manifest.get(prefill_name)?.clone();
        let prefill_seq = match &pspec.kind {
            ArtifactKind::Model { seq, .. } => *seq,
            _ => bail!("{prefill_name} is not a model artifact"),
        };
        // Weight order must agree between the two artifacts.
        if pspec.params != dspec.params {
            bail!("prefill/decode artifacts disagree on parameter spec");
        }
        let weights = weights_to_literals(model, &dspec.params)?;
        // Warm the compile cache.
        engine.executable(prefill_name)?;
        engine.executable(decode_name)?;
        Ok(Self {
            prefill_name: prefill_name.to_string(),
            decode_name: decode_name.to_string(),
            weights,
            batch,
            prefill_seq,
            max_seq,
            vocab,
            layers,
            dim,
        })
    }

    fn args_with_weights(&self, extra: Vec<xla::Literal>) -> Vec<xla::Literal> {
        let mut args: Vec<xla::Literal> = self.weights.to_vec();
        args.extend(extra);
        args
    }

    /// Run prefill (batch 1 artifact) on one prompt, padded to the
    /// artifact's static length. Returns (all-position logits, KvState).
    pub fn prefill(&self, engine: &mut Engine, prompt: &[usize]) -> Result<(Vec<f32>, KvState)> {
        if prompt.is_empty() || prompt.len() > self.prefill_seq {
            bail!("prompt length {} not in 1..={}", prompt.len(), self.prefill_seq);
        }
        let mut toks = vec![0i32; self.prefill_seq];
        for (i, &t) in prompt.iter().enumerate() {
            toks[i] = t as i32;
        }
        let tokens = literal_i32(&toks, &[1, self.prefill_seq])?;
        let out = engine.run(&self.prefill_name, &self.args_with_weights(vec![tokens]))?;
        if out.len() != 3 {
            bail!("prefill returned {} outputs, want 3", out.len());
        }
        let mut it = out.into_iter();
        let logits = it.next().unwrap().to_vec::<f32>()?;
        let k = it.next().unwrap();
        let v = it.next().unwrap();
        Ok((logits, KvState { k, v, pos: prompt.len() }))
    }

    /// Logits row for position `pos` out of a prefill result.
    pub fn logits_at(&self, logits: &[f32], pos: usize) -> Vec<f32> {
        logits[pos * self.vocab..(pos + 1) * self.vocab].to_vec()
    }

    /// One batched decode step. `tokens.len()` must equal the artifact
    /// batch; all sequences share `state.pos`.
    pub fn decode_step(
        &self,
        engine: &mut Engine,
        state: KvState,
        tokens: &[usize],
    ) -> Result<(Vec<Vec<f32>>, KvState)> {
        if tokens.len() != self.batch {
            bail!("decode batch {} != artifact batch {}", tokens.len(), self.batch);
        }
        if state.pos >= self.max_seq {
            bail!("KV cache full at pos {}", state.pos);
        }
        let tok: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        // NOTE: a device-resident-weights fast path via execute_b was
        // measured and reverted — see EXPERIMENTS.md §Perf (intermittent
        // size CHECK failures inside xla_extension 0.5.1's
        // buffer_from_host_literal under repeated staging).
        let args = self.args_with_weights(vec![
            state.k,
            state.v,
            literal_i32(&tok, &[self.batch])?,
            literal_i32(&[state.pos as i32], &[])?,
        ]);
        let out = engine.run(&self.decode_name, &args)?;
        if out.len() != 3 {
            bail!("decode returned {} outputs, want 3", out.len());
        }
        let mut it = out.into_iter();
        let logits_flat = it.next().unwrap().to_vec::<f32>()?;
        let k = it.next().unwrap();
        let v = it.next().unwrap();
        let logits = (0..self.batch)
            .map(|b| logits_flat[b * self.vocab..(b + 1) * self.vocab].to_vec())
            .collect();
        Ok((logits, KvState { k, v, pos: state.pos + 1 }))
    }

    /// Per-lane KV store sized for this runner's decode artifact.
    pub fn lane_kv(&self) -> LaneKv {
        LaneKv::new(self.layers, self.batch, self.max_seq, self.dim)
    }
}

/// Typed KV failure on the lane path, carrying the lane and sequence
/// position — so the serving layer can fail exactly the offending
/// session instead of killing the whole engine loop.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LaneKvError {
    pub lane: usize,
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for LaneKvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lane {} KV failure at position {}: {}", self.lane, self.pos, self.msg)
    }
}

impl std::error::Error for LaneKvError {}

/// Per-lane view over the decode KV cache, backed by the paged
/// [`BlockPool`] (DESIGN.md §8).
///
/// The decode artifact is lowered for a static batch `B` and a merged
/// `(L, B, S, d)` cache layout; continuous batching needs each batch row
/// ("lane") to carry an independent session. `LaneKv` keeps one block
/// table per lane — so lanes sharing a prompt prefix map the same
/// physical blocks — and materializes the merged contiguous literal only
/// at decode-call time via the kernel-layer gather
/// ([`gather::gather_merged`]); positions a lane has not written are
/// zero in the merged view.
pub struct LaneKv {
    pool: BlockPool,
    tables: Vec<Option<SeqKv>>,
    layers: usize,
    max_seq: usize,
    dim: usize,
    /// Zero row returned for unwritten positions by [`LaneKv::k_row`].
    zeros: Vec<f32>,
}

impl LaneKv {
    /// Pool sized to the same bytes as the old contiguous
    /// `layers × lanes × max_seq × dim` cache.
    pub fn new(layers: usize, lanes: usize, max_seq: usize, dim: usize) -> Self {
        let cfg = KvPoolConfig::matching_contiguous(layers, dim, lanes.max(1), max_seq);
        Self {
            pool: BlockPool::new(cfg),
            tables: (0..lanes.max(1)).map(|_| None).collect(),
            layers,
            max_seq,
            dim,
            zeros: vec![0f32; dim],
        }
    }

    pub fn lanes(&self) -> usize {
        self.tables.len()
    }

    /// Tokens currently cached on a lane (0 when unclaimed).
    pub fn pos(&self, lane: usize) -> usize {
        self.tables.get(lane).and_then(|t| t.as_ref()).map_or(0, |t| t.len())
    }

    /// Lanes currently holding a session table.
    pub fn active_lanes(&self) -> usize {
        self.tables.iter().filter(|t| t.is_some()).count()
    }

    pub fn stats(&self) -> KvPoolStats {
        self.pool.stats()
    }

    /// Blocks an allocation could obtain right now.
    pub fn allocatable_blocks(&self) -> usize {
        self.pool.allocatable_blocks()
    }

    /// Blocks needed for `tokens` positions (ignoring prefix sharing).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        self.pool.blocks_for(tokens)
    }

    fn fault(lane: usize, pos: usize, msg: impl Into<String>) -> LaneKvError {
        LaneKvError { lane, pos, msg: msg.into() }
    }

    /// Install a single-sequence `(L, 1, S, d)` prefill cache (the layout
    /// [`ModelRunner::prefill`] returns) for `tokens` into one lane.
    /// Rows already resident for a shared prompt prefix are reused
    /// instead of copied; returns how many leading positions were shared.
    ///
    /// Always installs the *whole* prompt: the PJRT prefill artifact is
    /// lowered for one full-sequence call, so the PJRT backend serves
    /// chunked-prefill scheduling (DESIGN.md §6) through the monolithic
    /// `DecodeBackend::prefill_chunk` fallback — correct, just without
    /// the decode-interleaving the native paged path gets.
    pub fn write_lane(
        &mut self,
        lane: usize,
        tokens: &[usize],
        k_seq: &[f32],
        v_seq: &[f32],
        pos: usize,
    ) -> Result<usize, LaneKvError> {
        if lane >= self.tables.len() {
            return Err(Self::fault(
                lane,
                pos,
                format!("lane out of range (lanes {})", self.tables.len()),
            ));
        }
        let stride = self.max_seq * self.dim;
        let want = self.layers * stride;
        if k_seq.len() != want || v_seq.len() != want {
            return Err(Self::fault(
                lane,
                pos,
                format!(
                    "per-lane cache has {} elements, artifact wants {want} (L*S*d)",
                    k_seq.len()
                ),
            ));
        }
        if pos > self.max_seq {
            return Err(Self::fault(
                lane,
                pos,
                format!("lane position exceeds max_seq {}", self.max_seq),
            ));
        }
        if tokens.len() != pos {
            return Err(Self::fault(
                lane,
                pos,
                format!("{} prompt tokens for position {pos}", tokens.len()),
            ));
        }
        // Stale table (re-prefill without reset): release it first.
        if let Some(old) = self.tables[lane].take() {
            self.pool.release(old);
        }
        let (mut seq, reused) = self.pool.begin(tokens);
        for t in reused..pos {
            if let Err(e) = self.pool.append(&mut seq, tokens[t]) {
                let p = e.pos();
                let msg = e.to_string();
                self.pool.release(seq);
                return Err(Self::fault(lane, p, msg));
            }
            for li in 0..self.layers {
                let src = li * stride + t * self.dim;
                self.pool
                    .k_row_mut(&seq, li, t)
                    .copy_from_slice(&k_seq[src..src + self.dim]);
                self.pool
                    .v_row_mut(&seq, li, t)
                    .copy_from_slice(&v_seq[src..src + self.dim]);
            }
        }
        self.tables[lane] = Some(seq);
        Ok(reused)
    }

    /// Free one lane's blocks (session finished/cancelled); other lanes
    /// — including ones sharing prefix blocks — are untouched.
    pub fn reset_lane(&mut self, lane: usize) {
        if let Some(seq) = self.tables.get_mut(lane).and_then(|t| t.take()) {
            self.pool.release(seq);
        }
    }

    /// Absorb one lane's freshly decoded KV row for `token` at `pos`
    /// out of the merged `(L, B, S, d)` decode output views.
    pub fn absorb_lane(
        &mut self,
        lane: usize,
        token: usize,
        k_new: &[f32],
        v_new: &[f32],
        pos: usize,
    ) -> Result<(), LaneKvError> {
        let lanes = self.tables.len();
        if lane >= lanes {
            return Err(Self::fault(lane, pos, format!("lane out of range (lanes {lanes})")));
        }
        if pos >= self.max_seq {
            return Err(Self::fault(
                lane,
                pos,
                format!("absorb position exceeds max_seq {}", self.max_seq),
            ));
        }
        let want = self.layers * lanes * self.max_seq * self.dim;
        if k_new.len() != want || v_new.len() != want {
            return Err(Self::fault(
                lane,
                pos,
                format!("decode KV output has {} elements, want {want}", k_new.len()),
            ));
        }
        let cur = self.pos(lane);
        if self.tables[lane].is_none() || cur != pos {
            return Err(Self::fault(
                lane,
                pos,
                format!("lane holds {cur} positions, artifact stepped at {pos}"),
            ));
        }
        let mut seq = self.tables[lane].take().expect("checked above");
        if let Err(e) = self.pool.append(&mut seq, token) {
            let p = e.pos();
            let msg = e.to_string();
            self.pool.release(seq);
            return Err(Self::fault(lane, p, msg));
        }
        for li in 0..self.layers {
            let src = ((li * lanes + lane) * self.max_seq + pos) * self.dim;
            self.pool
                .k_row_mut(&seq, li, pos)
                .copy_from_slice(&k_new[src..src + self.dim]);
            self.pool
                .v_row_mut(&seq, li, pos)
                .copy_from_slice(&v_new[src..src + self.dim]);
        }
        self.tables[lane] = Some(seq);
        Ok(())
    }

    /// Absorb a decode step for the given `(lane, token)` pairs at the
    /// shared position `pos` (the artifact writes a row for *every*
    /// batch slot; inactive lanes must not be absorbed). A per-lane
    /// fault does not abandon the remaining lanes — every lane is
    /// absorbed and the *first* fault is returned — matching the
    /// only-the-offending-session-fails contract.
    pub fn absorb_step(
        &mut self,
        active: &[(usize, usize)],
        k_new: &xla::Literal,
        v_new: &xla::Literal,
        pos: usize,
    ) -> Result<(), LaneKvError> {
        // A view-borrow failure predates any lane work; attribute it to
        // the first requested lane rather than inventing a sentinel.
        let lane0 = active.first().map_or(0, |&(lane, _)| lane);
        let kv = literal_f32_view(k_new)
            .map_err(|e| Self::fault(lane0, pos, format!("borrowing K view: {e:#}")))?;
        let vv = literal_f32_view(v_new)
            .map_err(|e| Self::fault(lane0, pos, format!("borrowing V view: {e:#}")))?;
        let mut first_err: Option<LaneKvError> = None;
        for &(lane, token) in active {
            if let Err(e) = self.absorb_lane(lane, token, kv, vv, pos) {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Gather the block tables into contiguous merged `(L, B, S, d)`
    /// K and V buffers (unwritten positions zero).
    fn merged(&self) -> (Vec<f32>, Vec<f32>) {
        let lanes = self.tables.len();
        let n = self.layers * lanes * self.max_seq * self.dim;
        let mut k = vec![0f32; n];
        let mut v = vec![0f32; n];
        let tables: Vec<Option<&SeqKv>> = self.tables.iter().map(|t| t.as_ref()).collect();
        gather::gather_merged(&self.pool, &tables, self.max_seq, &mut k, &mut v);
        (k, v)
    }

    /// Merged K and V caches as `(L, B, S, d)` literals for the decode
    /// artifact (one gather for both).
    pub fn merged_literals(&self) -> Result<(xla::Literal, xla::Literal)> {
        let (k, v) = self.merged();
        let dims = [self.layers, self.tables.len(), self.max_seq, self.dim];
        Ok((literal_f32(&k, &dims)?, literal_f32(&v, &dims)?))
    }

    /// Merged K cache as a `(L, B, S, d)` literal. Test/diagnostic
    /// accessor: it gathers *both* slabs and discards V — the decode
    /// path uses [`LaneKv::merged_literals`], which pays one gather for
    /// the pair.
    pub fn k_literal(&self) -> Result<xla::Literal> {
        Ok(self.merged_literals()?.0)
    }

    /// Merged V cache as a `(L, B, S, d)` literal (see [`LaneKv::k_literal`]).
    pub fn v_literal(&self) -> Result<xla::Literal> {
        Ok(self.merged_literals()?.1)
    }

    /// Host K row `(layer, lane, seq_pos)` — test/diagnostic accessor;
    /// zeros for unclaimed lanes / unwritten positions.
    pub fn k_row(&self, layer: usize, lane: usize, seq_pos: usize) -> &[f32] {
        match self.tables.get(lane).and_then(|t| t.as_ref()) {
            Some(t) if seq_pos < t.len() => self.pool.k_row(t, layer, seq_pos),
            _ => &self.zeros,
        }
    }
}

/// Greedy argmax over a logits row.
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::model::config::ModelConfig;
    use std::path::Path;

    fn artifact_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have(name: &str) -> bool {
        artifact_dir().join(format!("{name}.hlo.txt")).exists()
    }

    #[test]
    fn inv_perm_is_inverse_of_pivot_order() {
        let mut rng = Rng::new(401);
        let w: crate::linalg::Mat<f32> = crate::linalg::Mat::rand_low_rank(12, 10, 4, &mut rng);
        let p = crate::pifa::pivoting_factorization(&w, 4, crate::pifa::PivotStrategy::QrColumnPivot)
            .unwrap();
        let spec = TensorSpec { name: "l0.q.inv_perm".into(), dtype: "i32".into(), dims: vec![12] };
        // Build a model with that module to exercise tensor_for.
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 16,
            dim: 12,
            n_layers: 1,
            n_heads: 2,
            ffn_hidden: 12,
            max_seq: 8,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        };
        let model = crate::model::transformer::Transformer::new_random(&cfg, &mut rng);
        // q is 10x10 in this config; swap in a 12-out PIFA for shape test
        // only through direct call:
        let _ = model;
        // Direct check of the inverse construction:
        let mut inv = vec![0usize; 12];
        for (pos, &ch) in p.pivots.iter().chain(p.non_pivots.iter()).enumerate() {
            inv[ch] = pos;
        }
        // concat(rows of W_p, rows of C W_p) indexed by inv == W.
        let w_np = crate::linalg::matmul(&p.c, &p.w_p);
        for ch in 0..12 {
            let pos = inv[ch];
            let row = if pos < 4 { p.w_p.row(pos) } else { w_np.row(pos - 4) };
            for j in 0..10 {
                assert!((row[j] - w[(ch, j)]).abs() < 1e-4);
            }
        }
        let _ = spec;
    }

    /// Build an (L,1,S,d) per-sequence cache whose element at
    /// (li, t, j) is `base + li*100 + t*10 + j`.
    fn seq_cache(layers: usize, s: usize, d: usize, base: f32) -> Vec<f32> {
        (0..layers * s * d)
            .map(|idx| {
                let (li, rem) = (idx / (s * d), idx % (s * d));
                base + (li * 100 + (rem / d) * 10 + rem % d) as f32
            })
            .collect()
    }

    #[test]
    fn lane_kv_merged_layout_holds_written_rows_zeros_elsewhere() {
        let (l, b, s, d) = (2usize, 3usize, 4usize, 2usize);
        let mut kv = LaneKv::new(l, b, s, d);
        let k0 = seq_cache(l, s, d, 1000.0);
        let k2 = seq_cache(l, s, d, 9000.0);
        kv.write_lane(0, &[11, 12, 13], &k0, &k0, 3).unwrap();
        kv.write_lane(2, &[21], &k2, &k2, 1).unwrap();
        assert_eq!((kv.pos(0), kv.pos(1), kv.pos(2)), (3, 0, 1));
        // Reference merge: only the `pos` valid rows per lane land in the
        // merged `(L, B, S, d)` layout; everything else is zero.
        let stride = s * d;
        let mut want = vec![0f32; l * b * stride];
        for li in 0..l {
            for (lane, src, pos) in [(0usize, &k0, 3usize), (2, &k2, 1)] {
                let dst = (li * b + lane) * stride;
                let n = pos * d;
                want[dst..dst + n].copy_from_slice(&src[li * stride..li * stride + n]);
            }
        }
        assert_eq!(kv.k_literal().unwrap().to_vec::<f32>().unwrap(), want);
        assert_eq!(kv.v_literal().unwrap().to_vec::<f32>().unwrap(), want);
    }

    #[test]
    fn lane_kv_reset_clears_only_that_lane() {
        let (l, b, s, d) = (2usize, 2usize, 3usize, 2usize);
        let mut kv = LaneKv::new(l, b, s, d);
        let c0 = seq_cache(l, s, d, 100.0);
        let c1 = seq_cache(l, s, d, 500.0);
        kv.write_lane(0, &[1, 2], &c0, &c0, 2).unwrap();
        kv.write_lane(1, &[3, 4, 5], &c1, &c1, 3).unwrap();
        kv.reset_lane(0);
        assert_eq!((kv.pos(0), kv.pos(1)), (0, 3));
        assert!(kv.k_row(0, 0, 0).iter().all(|&x| x == 0.0));
        assert_eq!(kv.k_row(0, 1, 0), &c1[0..d]);
        // Re-prefetching the freed lane works without disturbing lane 1.
        kv.write_lane(0, &[1], &c0, &c0, 1).unwrap();
        assert_eq!(kv.k_row(1, 1, 2), &c1[(s + 2) * d..(s + 3) * d]);
    }

    #[test]
    fn lane_kv_absorb_updates_only_active_lanes() {
        let (l, b, s, d) = (1usize, 2usize, 3usize, 2usize);
        let mut kv = LaneKv::new(l, b, s, d);
        let c = seq_cache(l, s, d, 0.0);
        // Different prompts so the lanes do not share prefix blocks.
        kv.write_lane(0, &[5], &c, &c, 1).unwrap();
        kv.write_lane(1, &[6], &c, &c, 1).unwrap();
        // Fake decode output: every element 7.0 (the artifact writes all
        // batch rows at `pos`, active or not).
        let full = vec![7.0f32; l * b * s * d];
        let lit = literal_f32(&full, &[l, b, s, d]).unwrap();
        kv.absorb_step(&[(1, 9)], &lit, &lit, 1).unwrap();
        assert_eq!((kv.pos(0), kv.pos(1)), (1, 2));
        // Lane 1 absorbed the row at pos=1; lane 0 has no row there.
        assert_eq!(kv.k_row(0, 1, 1), &[7.0, 7.0]);
        assert!(kv.k_row(0, 0, 1).iter().all(|&x| x == 0.0));
        assert_eq!(kv.k_row(0, 0, 0), &c[0..d], "lane 0 prefill row intact");
    }

    #[test]
    fn lane_kv_shares_prompt_prefix_blocks_across_lanes() {
        let (l, b, s, d) = (1usize, 3usize, 32usize, 2usize);
        let mut kv = LaneKv::new(l, b, s, d);
        let c = seq_cache(l, s, d, 3000.0);
        let prompt: Vec<usize> = (40..40 + 20).collect();
        kv.write_lane(0, &prompt, &c, &c, 20).unwrap();
        let used_one = kv.stats().used_blocks;
        let reused = kv.write_lane(1, &prompt, &c, &c, 20).unwrap();
        assert_eq!(reused, 19, "all but the final prompt position shared");
        assert!(
            kv.stats().used_blocks <= used_one + 1,
            "shared prefix must not duplicate blocks: {} -> {}",
            used_one,
            kv.stats().used_blocks
        );
        assert_eq!(kv.k_row(0, 0, 5), kv.k_row(0, 1, 5), "same physical rows");
        kv.reset_lane(0);
        // Lane 1 still reads the shared rows after lane 0 released.
        assert_eq!(kv.k_row(0, 1, 5), &c[5 * d..6 * d]);
    }

    #[test]
    fn lane_kv_errors_are_typed_with_lane_and_position() {
        let mut kv = LaneKv::new(1, 2, 3, 2);
        let e = kv.write_lane(5, &[], &[0.0; 6], &[0.0; 6], 0).unwrap_err();
        assert_eq!((e.lane, e.pos), (5, 0));
        assert!(kv.write_lane(0, &[], &[0.0; 4], &[0.0; 4], 0).is_err());
        let ok = vec![0.0f32; 6];
        let e = kv.write_lane(0, &[1; 9], &ok, &ok, 9).unwrap_err();
        assert_eq!((e.lane, e.pos), (0, 9));
        assert!(kv.write_lane(0, &[1, 2], &ok, &ok, 3).is_err(), "token/pos mismatch");
        let lit = literal_f32(&[0.0f32; 12], &[1, 2, 3, 2]).unwrap();
        let e = kv.absorb_step(&[(0, 1)], &lit, &lit, 7).unwrap_err();
        assert_eq!((e.lane, e.pos), (0, 7));
        let e = kv.absorb_step(&[(9, 1)], &lit, &lit, 0).unwrap_err();
        assert_eq!(e.lane, 9);
        // Absorb at a position the lane has not reached is typed too.
        kv.write_lane(0, &[1], &ok, &ok, 1).unwrap();
        let e = kv.absorb_step(&[(0, 2)], &lit, &lit, 2).unwrap_err();
        assert_eq!((e.lane, e.pos), (0, 2));
        assert!(e.to_string().contains("lane 0"));
    }

    #[test]
    fn weights_to_literals_rejects_shape_mismatch() {
        let cfg = ModelConfig::tiny_s();
        let mut rng = Rng::new(402);
        let model = crate::model::transformer::Transformer::new_random(&cfg, &mut rng);
        let bad = TensorSpec { name: "embed".into(), dtype: "f32".into(), dims: vec![100, 64] };
        assert!(weights_to_literals(&model, &[bad]).is_err());
        let good = TensorSpec {
            name: "embed".into(),
            dtype: "f32".into(),
            dims: vec![cfg.vocab, cfg.dim],
        };
        assert!(weights_to_literals(&model, &[good]).is_ok());
    }

    /// End-to-end L2/L3 parity: PJRT output of the dense artifact matches
    /// the Rust-native forward on the same weights. The core cross-layer
    /// correctness test of the whole stack.
    #[test]
    fn pjrt_matches_rust_native_forward() {
        if !have("tiny-s_dense_prefill_b1_t64") {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut engine = Engine::new(&artifact_dir()).unwrap();
        let cfg = ModelConfig::tiny_s();
        let mut rng = Rng::new(403);
        let model = crate::model::transformer::Transformer::new_random(&cfg, &mut rng);
        let runner = ModelRunner::new(
            &mut engine,
            &model,
            "tiny-s_dense_prefill_b1_t64",
            "tiny-s_dense_decode_b1",
        )
        .unwrap();
        let prompt = [5usize, 17, 100, 42, 3, 9, 7, 1];
        let (logits, kv) = runner.prefill(&mut engine, &prompt).unwrap();
        // Rust-native forward on the padded sequence (prefill pads to 64).
        let mut padded = prompt.to_vec();
        padded.resize(64, 0);
        let native = model.forward(&padded, None);
        let last = runner.logits_at(&logits, prompt.len() - 1);
        for j in 0..cfg.vocab {
            let a = last[j];
            let b = native[(prompt.len() - 1, j)];
            assert!(
                (a - b).abs() < 2e-2_f32.max(b.abs() * 0.01),
                "logit {j}: pjrt {a} vs native {b}"
            );
        }
        // And one decode step continues correctly.
        let next = argmax(&last);
        let (dec_logits, _) = runner.decode_step(&mut engine, kv, &[next]).unwrap();
        let mut seq = prompt.to_vec();
        seq.push(next);
        let mut padded2 = seq.clone();
        padded2.resize(64, 0);
        let native2 = model.forward(&padded2, None);
        for j in 0..cfg.vocab {
            let a = dec_logits[0][j];
            let b = native2[(seq.len() - 1, j)];
            assert!(
                (a - b).abs() < 3e-2_f32.max(b.abs() * 0.02),
                "decode logit {j}: pjrt {a} vs native {b}"
            );
        }
    }
}
