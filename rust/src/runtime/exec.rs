//! Binds a Rust [`Transformer`] checkpoint to an AOT artifact and drives
//! prefill / decode through PJRT.

use super::loader::{literal_f32, literal_i32, Engine};
use super::manifest::{ArtifactKind, TensorSpec};
use crate::model::transformer::{ModuleKind, Transformer};
use crate::model::LinearRepr;
use anyhow::{bail, Context, Result};

fn kind_of(tag: &str) -> Result<ModuleKind> {
    Ok(match tag {
        "q" => ModuleKind::Q,
        "k" => ModuleKind::K,
        "v" => ModuleKind::V,
        "o" => ModuleKind::O,
        "gate" => ModuleKind::Gate,
        "up" => ModuleKind::Up,
        "down" => ModuleKind::Down,
        other => bail!("unknown module tag {other}"),
    })
}

/// Convert a checkpointed model into the artifact's canonical parameter
/// literals. Shapes are validated against the manifest — a mismatch means
/// the model was compressed with a different density/flavour than the
/// artifact was lowered for.
pub fn weights_to_literals(model: &Transformer, params: &[TensorSpec]) -> Result<Vec<xla::Literal>> {
    let mut out = Vec::with_capacity(params.len());
    for spec in params {
        let lit = tensor_for(model, spec)
            .with_context(|| format!("building literal for param '{}'", spec.name))?;
        out.push(lit);
    }
    Ok(out)
}

fn mat_literal(m: &crate::linalg::Mat<f32>, spec: &TensorSpec) -> Result<xla::Literal> {
    let want: Vec<usize> = spec.dims.clone();
    let got = vec![m.rows(), m.cols()];
    if want != got {
        bail!("shape mismatch: artifact wants {want:?}, model has {got:?}");
    }
    literal_f32(m.as_slice(), &spec.dims)
}

fn vec_literal(v: &[f32], spec: &TensorSpec) -> Result<xla::Literal> {
    if spec.dims != vec![v.len()] {
        bail!("shape mismatch: artifact wants {:?}, model has [{}]", spec.dims, v.len());
    }
    literal_f32(v, &spec.dims)
}

fn tensor_for(model: &Transformer, spec: &TensorSpec) -> Result<xla::Literal> {
    let name = spec.name.as_str();
    match name {
        "embed" => return mat_literal(&model.embed, spec),
        "head" => return mat_literal(&model.head, spec),
        "final_norm" => return vec_literal(&model.final_norm, spec),
        _ => {}
    }
    // l{i}.{field}[.{part}]
    let rest = name.strip_prefix('l').context("param name must start with l")?;
    let (layer_s, tail) = rest.split_once('.').context("missing layer dot")?;
    let layer: usize = layer_s.parse().context("bad layer index")?;
    if layer >= model.blocks.len() {
        bail!("layer {layer} out of range");
    }
    match tail {
        "attn_norm" => return vec_literal(&model.blocks[layer].attn_norm, spec),
        "mlp_norm" => return vec_literal(&model.blocks[layer].mlp_norm, spec),
        _ => {}
    }
    let (mod_tag, part) = tail.split_once('.').context("missing module part")?;
    let kind = kind_of(mod_tag)?;
    let repr = model.module(layer, kind);
    match (repr, part) {
        (LinearRepr::Dense(w), "w") => mat_literal(w, spec),
        (LinearRepr::LowRank { u, .. }, "u") => mat_literal(u, spec),
        (LinearRepr::LowRank { vt, .. }, "vt") => mat_literal(vt, spec),
        (LinearRepr::Pifa(p), "w_p") => mat_literal(&p.w_p, spec),
        (LinearRepr::Pifa(p), "c") => mat_literal(&p.c, spec),
        (LinearRepr::Pifa(p), "inv_perm") => {
            if spec.dims != vec![p.m] {
                bail!("inv_perm shape mismatch");
            }
            // Output channel i reads concat([pivots, non_pivots]) position
            // inv[i].
            let mut inv = vec![0i32; p.m];
            for (pos, &ch) in p.pivots.iter().chain(p.non_pivots.iter()).enumerate() {
                inv[ch] = pos as i32;
            }
            literal_i32(&inv, &spec.dims)
        }
        (r, p) => bail!(
            "model module l{layer}.{} is '{}' but artifact wants part '{p}'",
            mod_tag,
            r.kind_name()
        ),
    }
}

/// Drives one (model, artifact-pair) through PJRT: batch prefill + decode.
pub struct ModelRunner {
    pub prefill_name: String,
    pub decode_name: String,
    weights: Vec<xla::Literal>,
    pub batch: usize,
    pub prefill_seq: usize,
    pub max_seq: usize,
    pub vocab: usize,
    pub layers: usize,
    pub dim: usize,
}

/// Opaque KV-cache state held between decode steps (host literals).
pub struct KvState {
    pub k: xla::Literal,
    pub v: xla::Literal,
    pub pos: usize,
}

impl ModelRunner {
    /// Bind `model` to the given prefill/decode artifact pair.
    pub fn new(
        engine: &mut Engine,
        model: &Transformer,
        prefill_name: &str,
        decode_name: &str,
    ) -> Result<Self> {
        let dspec = engine.manifest.get(decode_name)?.clone();
        let (batch, max_seq, vocab, layers, dim) = match &dspec.kind {
            ArtifactKind::Model { batch, max_seq, vocab, layers, dim, .. } => {
                (*batch, *max_seq, *vocab, *layers, *dim)
            }
            _ => bail!("{decode_name} is not a model artifact"),
        };
        let pspec = engine.manifest.get(prefill_name)?.clone();
        let prefill_seq = match &pspec.kind {
            ArtifactKind::Model { seq, .. } => *seq,
            _ => bail!("{prefill_name} is not a model artifact"),
        };
        // Weight order must agree between the two artifacts.
        if pspec.params != dspec.params {
            bail!("prefill/decode artifacts disagree on parameter spec");
        }
        let weights = weights_to_literals(model, &dspec.params)?;
        // Warm the compile cache.
        engine.executable(prefill_name)?;
        engine.executable(decode_name)?;
        Ok(Self {
            prefill_name: prefill_name.to_string(),
            decode_name: decode_name.to_string(),
            weights,
            batch,
            prefill_seq,
            max_seq,
            vocab,
            layers,
            dim,
        })
    }

    fn args_with_weights(&self, extra: Vec<xla::Literal>) -> Vec<xla::Literal> {
        let mut args: Vec<xla::Literal> = self.weights.to_vec();
        args.extend(extra);
        args
    }

    /// Run prefill (batch 1 artifact) on one prompt, padded to the
    /// artifact's static length. Returns (all-position logits, KvState).
    pub fn prefill(&self, engine: &mut Engine, prompt: &[usize]) -> Result<(Vec<f32>, KvState)> {
        if prompt.is_empty() || prompt.len() > self.prefill_seq {
            bail!("prompt length {} not in 1..={}", prompt.len(), self.prefill_seq);
        }
        let mut toks = vec![0i32; self.prefill_seq];
        for (i, &t) in prompt.iter().enumerate() {
            toks[i] = t as i32;
        }
        let tokens = literal_i32(&toks, &[1, self.prefill_seq])?;
        let out = engine.run(&self.prefill_name, &self.args_with_weights(vec![tokens]))?;
        if out.len() != 3 {
            bail!("prefill returned {} outputs, want 3", out.len());
        }
        let mut it = out.into_iter();
        let logits = it.next().unwrap().to_vec::<f32>()?;
        let k = it.next().unwrap();
        let v = it.next().unwrap();
        Ok((logits, KvState { k, v, pos: prompt.len() }))
    }

    /// Logits row for position `pos` out of a prefill result.
    pub fn logits_at(&self, logits: &[f32], pos: usize) -> Vec<f32> {
        logits[pos * self.vocab..(pos + 1) * self.vocab].to_vec()
    }

    /// One batched decode step. `tokens.len()` must equal the artifact
    /// batch; all sequences share `state.pos`.
    pub fn decode_step(
        &self,
        engine: &mut Engine,
        state: KvState,
        tokens: &[usize],
    ) -> Result<(Vec<Vec<f32>>, KvState)> {
        if tokens.len() != self.batch {
            bail!("decode batch {} != artifact batch {}", tokens.len(), self.batch);
        }
        if state.pos >= self.max_seq {
            bail!("KV cache full at pos {}", state.pos);
        }
        let tok: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        // NOTE: a device-resident-weights fast path via execute_b was
        // measured and reverted — see EXPERIMENTS.md §Perf (intermittent
        // size CHECK failures inside xla_extension 0.5.1's
        // buffer_from_host_literal under repeated staging).
        let args = self.args_with_weights(vec![
            state.k,
            state.v,
            literal_i32(&tok, &[self.batch])?,
            literal_i32(&[state.pos as i32], &[])?,
        ]);
        let out = engine.run(&self.decode_name, &args)?;
        if out.len() != 3 {
            bail!("decode returned {} outputs, want 3", out.len());
        }
        let mut it = out.into_iter();
        let logits_flat = it.next().unwrap().to_vec::<f32>()?;
        let k = it.next().unwrap();
        let v = it.next().unwrap();
        let logits = (0..self.batch)
            .map(|b| logits_flat[b * self.vocab..(b + 1) * self.vocab].to_vec())
            .collect();
        Ok((logits, KvState { k, v, pos: state.pos + 1 }))
    }

    /// Fresh zeroed KV state (for decode-from-scratch generation).
    pub fn empty_kv(&self) -> Result<KvState> {
        let n = self.layers * self.batch * self.max_seq * self.dim;
        let dims = [self.layers, self.batch, self.max_seq, self.dim];
        Ok(KvState {
            k: literal_f32(&vec![0f32; n], &dims)?,
            v: literal_f32(&vec![0f32; n], &dims)?,
            pos: 0,
        })
    }
}

/// Greedy argmax over a logits row.
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::model::config::ModelConfig;
    use std::path::Path;

    fn artifact_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have(name: &str) -> bool {
        artifact_dir().join(format!("{name}.hlo.txt")).exists()
    }

    #[test]
    fn inv_perm_is_inverse_of_pivot_order() {
        let mut rng = Rng::new(401);
        let w: crate::linalg::Mat<f32> = crate::linalg::Mat::rand_low_rank(12, 10, 4, &mut rng);
        let p = crate::pifa::pivoting_factorization(&w, 4, crate::pifa::PivotStrategy::QrColumnPivot)
            .unwrap();
        let spec = TensorSpec { name: "l0.q.inv_perm".into(), dtype: "i32".into(), dims: vec![12] };
        // Build a model with that module to exercise tensor_for.
        let cfg = ModelConfig {
            name: "t".into(),
            vocab: 16,
            dim: 12,
            n_layers: 1,
            n_heads: 2,
            ffn_hidden: 12,
            max_seq: 8,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        };
        let model = crate::model::transformer::Transformer::new_random(&cfg, &mut rng);
        // q is 10x10 in this config; swap in a 12-out PIFA for shape test
        // only through direct call:
        let _ = model;
        // Direct check of the inverse construction:
        let mut inv = vec![0usize; 12];
        for (pos, &ch) in p.pivots.iter().chain(p.non_pivots.iter()).enumerate() {
            inv[ch] = pos;
        }
        // concat(rows of W_p, rows of C W_p) indexed by inv == W.
        let w_np = crate::linalg::matmul(&p.c, &p.w_p);
        for ch in 0..12 {
            let pos = inv[ch];
            let row = if pos < 4 { p.w_p.row(pos) } else { w_np.row(pos - 4) };
            for j in 0..10 {
                assert!((row[j] - w[(ch, j)]).abs() < 1e-4);
            }
        }
        let _ = spec;
    }

    #[test]
    fn weights_to_literals_rejects_shape_mismatch() {
        let cfg = ModelConfig::tiny_s();
        let mut rng = Rng::new(402);
        let model = crate::model::transformer::Transformer::new_random(&cfg, &mut rng);
        let bad = TensorSpec { name: "embed".into(), dtype: "f32".into(), dims: vec![100, 64] };
        assert!(weights_to_literals(&model, &[bad]).is_err());
        let good = TensorSpec {
            name: "embed".into(),
            dtype: "f32".into(),
            dims: vec![cfg.vocab, cfg.dim],
        };
        assert!(weights_to_literals(&model, &[good]).is_ok());
    }

    /// End-to-end L2/L3 parity: PJRT output of the dense artifact matches
    /// the Rust-native forward on the same weights. The core cross-layer
    /// correctness test of the whole stack.
    #[test]
    fn pjrt_matches_rust_native_forward() {
        if !have("tiny-s_dense_prefill_b1_t64") {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut engine = Engine::new(&artifact_dir()).unwrap();
        let cfg = ModelConfig::tiny_s();
        let mut rng = Rng::new(403);
        let model = crate::model::transformer::Transformer::new_random(&cfg, &mut rng);
        let runner = ModelRunner::new(
            &mut engine,
            &model,
            "tiny-s_dense_prefill_b1_t64",
            "tiny-s_dense_decode_b1",
        )
        .unwrap();
        let prompt = [5usize, 17, 100, 42, 3, 9, 7, 1];
        let (logits, kv) = runner.prefill(&mut engine, &prompt).unwrap();
        // Rust-native forward on the padded sequence (prefill pads to 64).
        let mut padded = prompt.to_vec();
        padded.resize(64, 0);
        let native = model.forward(&padded, None);
        let last = runner.logits_at(&logits, prompt.len() - 1);
        for j in 0..cfg.vocab {
            let a = last[j];
            let b = native[(prompt.len() - 1, j)];
            assert!(
                (a - b).abs() < 2e-2_f32.max(b.abs() * 0.01),
                "logit {j}: pjrt {a} vs native {b}"
            );
        }
        // And one decode step continues correctly.
        let next = argmax(&last);
        let (dec_logits, _) = runner.decode_step(&mut engine, kv, &[next]).unwrap();
        let mut seq = prompt.to_vec();
        seq.push(next);
        let mut padded2 = seq.clone();
        padded2.resize(64, 0);
        let native2 = model.forward(&padded2, None);
        for j in 0..cfg.vocab {
            let a = dec_logits[0][j];
            let b = native2[(seq.len() - 1, j)];
            assert!(
                (a - b).abs() < 3e-2_f32.max(b.abs() * 0.02),
                "decode logit {j}: pjrt {a} vs native {b}"
            );
        }
    }
}
