//! Paged KV-cache block pool with copy-on-write prefix sharing
//! (DESIGN.md §8).
//!
//! The contiguous serving caches preallocated a dense
//! `layers × lanes × max_seq × dim` buffer per engine, so lane count was
//! fixed at startup and a three-token session paid for `max_seq` rows.
//! [`BlockPool`] replaces that with a pool of fixed-size *blocks*
//! (`block_tokens` rows × `layers` × `dim` each); a session holds a
//! [`SeqKv`] block table mapping positions to physical blocks, and blocks
//! are:
//!
//! * **ref-counted** — sessions whose token prefixes agree map the *same*
//!   physical blocks (system prompts, repeated tab7 evals);
//! * **content-addressed** — a chain hash over the token prefix indexes
//!   every resident block, so [`BlockPool::begin`] can re-attach a new
//!   session to already-computed K/V rows;
//! * **copy-on-write** — appending into a block another session still
//!   references forks a private copy first ([`BlockPool::append`]), so a
//!   shared prefix can diverge mid-block without corrupting the peer;
//! * **retained after release** — a block whose refcount drops to zero
//!   parks on an idle queue, still indexed, and is only evicted (oldest
//!   first) when an allocation needs it. Sequential sessions with the
//!   same prompt therefore still hit the prefix cache.
//!
//! K/V rows are a pure function of the token prefix (causal attention +
//! deterministic kernels), which is what makes content-addressed sharing
//! sound — and why the paged path can be *bitwise* identical to the
//! contiguous one (`rust/tests/kv_differential.rs`).
//!
//! Single-owner discipline: the pool is owned by one decode backend and
//! mutated only between parallel sections. The kernel-layer views that
//! read/write slabs during a parallel decode step live in
//! [`crate::runtime::kernels::gather`].

use crate::model::transformer::{KvStore, KvStoreFull};
use crate::runtime::kvlife::EvictPolicyKind;
use std::collections::{HashMap, VecDeque};
use std::fmt;

/// Default tokens per block (vLLM-style granularity; small enough that a
/// short session wastes at most one partial block per layer).
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Geometry of a [`BlockPool`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KvPoolConfig {
    pub layers: usize,
    pub dim: usize,
    /// Token rows per block.
    pub block_tokens: usize,
    /// Physical blocks in the pool.
    pub num_blocks: usize,
}

impl KvPoolConfig {
    /// A pool holding the bytes of a contiguous `lanes × max_seq` cache
    /// (the fixed-lane baseline), rounded up to whole blocks per lane —
    /// exact when `block_tokens` divides `max_seq`, as with the default
    /// 16 and the tiny-model family's `max_seq = 128`; otherwise the
    /// pool is at most one block per lane larger.
    pub fn matching_contiguous(layers: usize, dim: usize, lanes: usize, max_seq: usize) -> Self {
        let block_tokens = DEFAULT_BLOCK_TOKENS.min(max_seq.max(1));
        Self {
            layers,
            dim,
            block_tokens,
            num_blocks: lanes.max(1) * max_seq.max(1).div_ceil(block_tokens),
        }
    }

    /// f32 elements per block (one K or V slab).
    pub fn block_elems(&self) -> usize {
        self.layers * self.block_tokens * self.dim
    }
}

/// Typed per-session KV failure: carries the position so the serving
/// layer can fail exactly the offending session.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvError {
    /// No free block and nothing evictable at append time.
    Exhausted { pos: usize },
    /// Position outside the caller-enforced capacity.
    Bounds { pos: usize, cap: usize },
}

impl KvError {
    /// The sequence position at which the failure occurred.
    pub fn pos(&self) -> usize {
        match *self {
            KvError::Exhausted { pos } => pos,
            KvError::Bounds { pos, .. } => pos,
        }
    }
}

impl fmt::Display for KvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvError::Exhausted { pos } => {
                write!(f, "KV block pool exhausted at position {pos}")
            }
            KvError::Bounds { pos, cap } => {
                write!(f, "KV position {pos} exceeds capacity {cap}")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// Aggregate pool counters, surfaced in `ServeMetrics` and the
/// `pifa serve` / tab7 / bench-kernels output.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct KvPoolStats {
    pub num_blocks: usize,
    /// Blocks referenced by at least one live session.
    pub used_blocks: usize,
    /// Blocks allocatable right now (never-used + idle-evictable).
    pub free_blocks: usize,
    /// Idle blocks retained for prefix reuse (subset of `free_blocks`).
    pub idle_blocks: usize,
    pub peak_used_blocks: usize,
    /// Prompt positions served from resident blocks by [`BlockPool::begin`].
    pub prefix_hit_tokens: usize,
    /// Prompt positions eligible for prefix matching.
    pub prefix_query_tokens: usize,
    /// Copy-on-write forks taken by [`BlockPool::append`].
    pub cow_copies: usize,
    /// Idle blocks sacrificed to allocations (prefix-index entries lost).
    pub evictions: usize,
}

impl KvPoolStats {
    /// Fraction of pool blocks holding live session data.
    pub fn utilization(&self) -> f64 {
        if self.num_blocks == 0 {
            0.0
        } else {
            self.used_blocks as f64 / self.num_blocks as f64
        }
    }

    /// Fraction of eligible prompt positions served from the cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_query_tokens == 0 {
            0.0
        } else {
            self.prefix_hit_tokens as f64 / self.prefix_query_tokens as f64
        }
    }
}

/// Per-session block table: positions `0..len` map to rows of the listed
/// physical blocks, `block_tokens` positions per block.
#[derive(Clone, Debug, Default)]
pub struct SeqKv {
    blocks: Vec<usize>,
    len: usize,
    /// Chain hash of the `len` tokens cached so far.
    hash: u64,
}

impl SeqKv {
    /// Tokens cached (the next write position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Physical block ids backing this session, in position order.
    pub fn blocks(&self) -> &[usize] {
        &self.blocks
    }
}

/// Root of the token chain hash (arbitrary non-zero constant).
const ROOT_HASH: u64 = 0x517c_c1b7_2722_0a95;

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Extend a token-prefix chain hash by one token.
fn chain(h: u64, token: usize) -> u64 {
    splitmix64(h ^ (token as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93))
}

/// Chain hash of a whole token prefix, from the pool root. This is the
/// exact hash the pool's sharing index is keyed by, exposed so
/// fleet-level placement (the router tier, DESIGN.md §12) can address
/// block content without touching a pool: equal prefixes hash equal on
/// every replica.
pub fn prefix_chain_hash(tokens: &[usize]) -> u64 {
    tokens.iter().fold(ROOT_HASH, |h, &t| chain(h, t))
}

/// Chain hashes of `tokens` at every `stride`-token boundary plus the
/// full length (shortest first, deduped by construction). The router
/// records these at placement time and looks them up longest-first, so
/// a new prompt lands on the replica holding its longest already-placed
/// prefix. Empty prompts yield no points (nothing to colocate on).
pub fn prefix_chain_points(tokens: &[usize], stride: usize) -> Vec<u64> {
    let stride = stride.max(1);
    let mut out = Vec::with_capacity(tokens.len() / stride + 1);
    let mut h = ROOT_HASH;
    for (i, &t) in tokens.iter().enumerate() {
        h = chain(h, t);
        if (i + 1) % stride == 0 || i + 1 == tokens.len() {
            out.push(h);
        }
    }
    out
}

#[derive(Clone, Debug, Default)]
struct BlockMeta {
    refs: usize,
    /// Token ids whose K/V rows fill this block, in row order.
    tokens: Vec<usize>,
    /// Chain hash of every token before this block.
    parent_hash: u64,
    /// Present in the `children` sharing index.
    registered: bool,
    /// Pool tick of the last allocation, prefix re-attach, or append.
    last_touch: u64,
    /// Prefix-cache re-attaches served by this block.
    hits: u64,
}

/// The physical block pool (see module docs).
pub struct BlockPool {
    cfg: KvPoolConfig,
    k: Vec<f32>,
    v: Vec<f32>,
    meta: Vec<BlockMeta>,
    /// Never-used or fully evicted blocks.
    free: Vec<usize>,
    /// refs == 0 but still indexed for prefix reuse; evicted oldest-first.
    idle: VecDeque<usize>,
    /// parent chain hash → candidate blocks holding the next tokens.
    children: HashMap<u64, Vec<usize>>,
    /// Which idle block to sacrifice when the free list is empty.
    policy: EvictPolicyKind,
    /// Logical clock driving `BlockMeta::last_touch`.
    tick: u64,
    prefix_hit_tokens: usize,
    prefix_query_tokens: usize,
    cow_copies: usize,
    evictions: usize,
    peak_used: usize,
}

impl BlockPool {
    pub fn new(cfg: KvPoolConfig) -> Self {
        assert!(cfg.layers > 0 && cfg.dim > 0, "degenerate pool geometry");
        assert!(cfg.block_tokens > 0 && cfg.num_blocks > 0, "empty pool");
        let elems = cfg.num_blocks * cfg.block_elems();
        Self {
            k: vec![0f32; elems],
            v: vec![0f32; elems],
            meta: (0..cfg.num_blocks).map(|_| BlockMeta::default()).collect(),
            free: (0..cfg.num_blocks).rev().collect(),
            idle: VecDeque::new(),
            children: HashMap::new(),
            policy: EvictPolicyKind::default(),
            tick: 0,
            prefix_hit_tokens: 0,
            prefix_query_tokens: 0,
            cow_copies: 0,
            evictions: 0,
            peak_used: 0,
            cfg,
        }
    }

    pub fn config(&self) -> &KvPoolConfig {
        &self.cfg
    }

    /// Select the idle-block eviction policy (DESIGN.md §10).
    pub fn set_policy(&mut self, policy: EvictPolicyKind) {
        self.policy = policy;
    }

    pub fn policy(&self) -> EvictPolicyKind {
        self.policy
    }

    /// Blocks an allocation could obtain right now.
    pub fn allocatable_blocks(&self) -> usize {
        self.free.len() + self.idle.len()
    }

    /// Blocks needed to hold `tokens` positions (ignoring sharing).
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.block_tokens)
    }

    pub fn stats(&self) -> KvPoolStats {
        let used = self.cfg.num_blocks - self.free.len() - self.idle.len();
        KvPoolStats {
            num_blocks: self.cfg.num_blocks,
            used_blocks: used,
            free_blocks: self.allocatable_blocks(),
            idle_blocks: self.idle.len(),
            peak_used_blocks: self.peak_used,
            prefix_hit_tokens: self.prefix_hit_tokens,
            prefix_query_tokens: self.prefix_query_tokens,
            cow_copies: self.cow_copies,
            evictions: self.evictions,
        }
    }

    /// Advance the logical clock and stamp block `b` as just touched.
    fn touch(&mut self, b: usize) {
        self.tick += 1;
        self.meta[b].last_touch = self.tick;
    }

    fn note_used(&mut self) {
        let used = self.cfg.num_blocks - self.free.len() - self.idle.len();
        self.peak_used = self.peak_used.max(used);
    }

    /// Drop a block from the sharing index and clear its token list.
    fn unregister(&mut self, b: usize) {
        if self.meta[b].registered {
            let parent = self.meta[b].parent_hash;
            if let Some(sibs) = self.children.get_mut(&parent) {
                sibs.retain(|&x| x != b);
                if sibs.is_empty() {
                    self.children.remove(&parent);
                }
            }
            self.meta[b].registered = false;
        }
        self.meta[b].tokens.clear();
    }

    /// Pop a writable block: the free list first, then sacrifice the
    /// idle (refs == 0) block the eviction policy picks — insertion
    /// order under FIFO, stalest touch under LRU, fewest prefix hits
    /// under Freq.
    fn alloc(&mut self) -> Option<usize> {
        if let Some(b) = self.free.pop() {
            return Some(b);
        }
        if self.idle.is_empty() {
            return None;
        }
        let i = match self.policy {
            EvictPolicyKind::Fifo => 0,
            _ => {
                let cands: Vec<(u64, u64)> = self
                    .idle
                    .iter()
                    .map(|&b| (self.meta[b].last_touch, self.meta[b].hits))
                    .collect();
                self.policy.pick(&cands)
            }
        };
        let b = self.idle.remove(i).expect("victim index within the idle queue");
        self.unregister(b);
        self.evictions += 1;
        Some(b)
    }

    /// Bump a matched block's refcount, pulling it off the idle queue if
    /// it was retained with zero references.
    fn retain_block(&mut self, b: usize) {
        if self.meta[b].refs == 0 {
            if let Some(i) = self.idle.iter().position(|&x| x == b) {
                self.idle.remove(i);
            }
        }
        self.meta[b].refs += 1;
    }

    /// Register a block under its parent chain hash so later sessions
    /// can discover it.
    fn register(&mut self, b: usize, parent_hash: u64) {
        self.meta[b].parent_hash = parent_hash;
        self.meta[b].registered = true;
        self.children.entry(parent_hash).or_default().push(b);
    }

    /// Start a session over `tokens` (its prompt). Walks the sharing
    /// index and attaches the longest resident prefix; returns the table
    /// plus how many leading positions are already cached. Matching is
    /// capped at `tokens.len() - 1`: prefill must always recompute the
    /// final prompt position, because its logits are needed.
    ///
    /// Chunked prefill (DESIGN.md §6) calls this once, on its *first*
    /// chunk, for the whole prompt: the reused prefix is attached as a
    /// free cursor jump (it never counts against the chunk budget) and
    /// only the recomputed tail is split across iterations.
    pub fn begin(&mut self, tokens: &[usize]) -> (SeqKv, usize) {
        let mut seq = SeqKv { blocks: Vec::new(), len: 0, hash: ROOT_HASH };
        let limit = tokens.len().saturating_sub(1);
        self.prefix_query_tokens += limit;
        let bt = self.cfg.block_tokens;
        while seq.len < limit {
            let want = &tokens[seq.len..limit];
            // Longest-matching child under the current chain hash.
            let mut best: Option<(usize, usize)> = None;
            if let Some(cands) = self.children.get(&seq.hash) {
                for &b in cands {
                    let have = &self.meta[b].tokens;
                    let mut m = 0;
                    while m < want.len() && m < have.len() && have[m] == want[m] {
                        m += 1;
                    }
                    let beats = match best {
                        Some((_, bm)) => m > bm,
                        None => m > 0,
                    };
                    if beats {
                        best = Some((b, m));
                    }
                }
            }
            let Some((b, m)) = best else { break };
            self.retain_block(b);
            self.meta[b].hits += 1;
            self.touch(b);
            seq.blocks.push(b);
            for &t in &tokens[seq.len..seq.len + m] {
                seq.hash = chain(seq.hash, t);
            }
            seq.len += m;
            self.prefix_hit_tokens += m;
            if m < bt {
                // Partial block (or partial match): nothing deeper can
                // match, and the session will COW-fork it on append.
                break;
            }
        }
        self.note_used();
        let reused = seq.len;
        (seq, reused)
    }

    /// Make position `seq.len()` writable for `token`: allocates a fresh
    /// block at block boundaries, copy-on-write-forks a shared partial
    /// block, records the token in the sharing index, and advances the
    /// session. The row contents are then written per layer through
    /// [`BlockPool::k_row_mut`] / [`BlockPool::v_row_mut`] (or the
    /// kernel-layer views).
    pub fn append(&mut self, seq: &mut SeqKv, token: usize) -> Result<(), KvError> {
        let bt = self.cfg.block_tokens;
        let pos = seq.len;
        let off = pos % bt;
        if off == 0 {
            let Some(b) = self.alloc() else {
                return Err(KvError::Exhausted { pos });
            };
            self.unregister(b); // fresh blocks carry no stale index entry
            self.meta[b].refs = 1;
            self.register(b, seq.hash);
            seq.blocks.push(b);
        } else {
            let bi = pos / bt;
            let b = seq.blocks[bi];
            if self.meta[b].refs > 1 {
                // Copy-on-write fork: private copy of the rows this
                // session actually shares, then diverge in the copy.
                let Some(nb) = self.alloc() else {
                    return Err(KvError::Exhausted { pos });
                };
                self.unregister(nb);
                self.copy_rows(b, nb, off);
                self.meta[nb].refs = 1;
                self.meta[nb].tokens = self.meta[b].tokens[..off].to_vec();
                let parent = self.meta[b].parent_hash;
                self.register(nb, parent);
                self.meta[b].refs -= 1;
                seq.blocks[bi] = nb;
                self.cow_copies += 1;
            } else if self.meta[b].tokens.len() > off {
                // Sole owner of a block longer than this session's view
                // (a partial match whose other holder released):
                // truncate the stale tail before overwriting it.
                self.meta[b].tokens.truncate(off);
            }
        }
        let b = *seq.blocks.last().expect("append always has a last block");
        debug_assert_eq!(self.meta[b].tokens.len(), off, "token list out of sync");
        self.meta[b].tokens.push(token);
        self.touch(b);
        seq.hash = chain(seq.hash, token);
        seq.len += 1;
        self.note_used();
        Ok(())
    }

    /// Copy the first `rows` K/V rows of every layer from `src` to `dst`.
    fn copy_rows(&mut self, src: usize, dst: usize, rows: usize) {
        if rows == 0 {
            return;
        }
        let d = self.cfg.dim;
        for layer in 0..self.cfg.layers {
            let s = self.row_offset(src, layer, 0);
            let t = self.row_offset(dst, layer, 0);
            let n = rows * d;
            self.k.copy_within(s..s + n, t);
            self.v.copy_within(s..s + n, t);
        }
    }

    /// Release a session: every block it references drops one refcount;
    /// blocks reaching zero park on the idle queue (still indexed) for
    /// prefix reuse until an allocation evicts them.
    pub fn release(&mut self, seq: SeqKv) {
        for &b in &seq.blocks {
            debug_assert!(self.meta[b].refs > 0, "double release of block {b}");
            self.meta[b].refs -= 1;
            if self.meta[b].refs == 0 {
                self.idle.push_back(b);
            }
        }
    }

    /// Truncate a session to its first `pos` positions — the speculative
    /// rollback primitive (DESIGN.md §11): rejected draft tokens are
    /// discarded by shrinking the *block table*, never by touching row
    /// contents. Whole blocks past the cut drop one refcount each (and
    /// park idle at zero, exactly like [`BlockPool::release`]). The
    /// boundary block that keeps a partial row range is deliberately
    /// **not** mutated: its token list may retain a stale tail, but a
    /// shared (refs > 1) block may back a peer's longer view, and
    /// [`BlockPool::append`] already handles divergence lazily — a COW
    /// fork when shared, a token-list truncation when solely owned. The
    /// chain hash rewinds by re-chaining the kept tokens, so prefix
    /// sharing and later appends see a consistent content address.
    /// Positions at or past `seq.len()` are a no-op.
    pub fn truncate(&mut self, seq: &mut SeqKv, pos: usize) {
        if pos >= seq.len {
            return;
        }
        let kept: Vec<usize> = {
            let all = self.tokens_of(seq);
            all[..pos].to_vec()
        };
        let first_dropped = pos.div_ceil(self.cfg.block_tokens);
        let dropped: Vec<usize> = seq.blocks.drain(first_dropped..).collect();
        for b in dropped {
            debug_assert!(self.meta[b].refs > 0, "truncate dropped block {b} twice");
            self.meta[b].refs -= 1;
            if self.meta[b].refs == 0 {
                self.idle.push_back(b);
            }
        }
        seq.len = pos;
        seq.hash = kept.iter().fold(ROOT_HASH, |h, &t| chain(h, t));
    }

    /// Flat element offset of `(block, layer, row)` in the K/V slabs.
    #[inline]
    fn row_offset(&self, block: usize, layer: usize, row: usize) -> usize {
        ((block * self.cfg.layers + layer) * self.cfg.block_tokens + row) * self.cfg.dim
    }

    /// `(block, row-within-block)` for a session position.
    #[inline]
    pub fn locate(&self, seq: &SeqKv, pos: usize) -> (usize, usize) {
        (seq.blocks[pos / self.cfg.block_tokens], pos % self.cfg.block_tokens)
    }

    pub fn k_row(&self, seq: &SeqKv, layer: usize, pos: usize) -> &[f32] {
        let (b, r) = self.locate(seq, pos);
        let at = self.row_offset(b, layer, r);
        &self.k[at..at + self.cfg.dim]
    }

    pub fn v_row(&self, seq: &SeqKv, layer: usize, pos: usize) -> &[f32] {
        let (b, r) = self.locate(seq, pos);
        let at = self.row_offset(b, layer, r);
        &self.v[at..at + self.cfg.dim]
    }

    pub fn k_row_mut(&mut self, seq: &SeqKv, layer: usize, pos: usize) -> &mut [f32] {
        let (b, r) = self.locate(seq, pos);
        let at = self.row_offset(b, layer, r);
        &mut self.k[at..at + self.cfg.dim]
    }

    pub fn v_row_mut(&mut self, seq: &SeqKv, layer: usize, pos: usize) -> &mut [f32] {
        let (b, r) = self.locate(seq, pos);
        let at = self.row_offset(b, layer, r);
        &mut self.v[at..at + self.cfg.dim]
    }

    /// Raw slab pointers + geometry for the kernel layer's parallel lane
    /// views (`runtime::kernels::gather`); see there for the
    /// disjointness argument.
    pub(crate) fn slab_ptrs(&mut self) -> (*mut f32, *mut f32) {
        (self.k.as_mut_ptr(), self.v.as_mut_ptr())
    }

    /// The token ids whose K/V rows a session caches, reconstructed
    /// from its blocks' metadata (spill needs them to re-import by
    /// content address later).
    pub fn tokens_of(&self, seq: &SeqKv) -> Vec<usize> {
        let mut out = Vec::with_capacity(seq.len);
        'outer: for &b in &seq.blocks {
            for &t in &self.meta[b].tokens {
                if out.len() == seq.len {
                    break 'outer;
                }
                out.push(t);
            }
        }
        debug_assert_eq!(out.len(), seq.len, "block token lists shorter than the session");
        out
    }

    /// Copy a session's K and V rows into contiguous host buffers,
    /// layer-major: element `(layer * len + pos) * dim + j`. The inverse
    /// of [`BlockPool::import_kv`].
    pub fn export_kv(&self, seq: &SeqKv) -> (Vec<f32>, Vec<f32>) {
        let (n, d) = (seq.len, self.cfg.dim);
        let mut k = vec![0f32; self.cfg.layers * n * d];
        let mut v = vec![0f32; self.cfg.layers * n * d];
        for layer in 0..self.cfg.layers {
            for pos in 0..n {
                let at = (layer * n + pos) * d;
                k[at..at + d].copy_from_slice(self.k_row(seq, layer, pos));
                v[at..at + d].copy_from_slice(self.v_row(seq, layer, pos));
            }
        }
        (k, v)
    }

    /// Rebuild a session table from spilled state: re-attach whatever
    /// prefix of `tokens` is still resident (content-addressed, exactly
    /// like [`BlockPool::begin`] but over the *full* token list and
    /// without prefix-rate accounting — a resume is not a prompt
    /// arrival), then allocate and rewrite the rest from the exported
    /// `k`/`v` buffers. On failure the partial table is released and the
    /// pool is unchanged up to eviction of idle blocks.
    pub fn import_kv(&mut self, tokens: &[usize], k: &[f32], v: &[f32]) -> Result<SeqKv, KvError> {
        let (n, d) = (tokens.len(), self.cfg.dim);
        debug_assert_eq!(k.len(), self.cfg.layers * n * d, "import K geometry mismatch");
        debug_assert_eq!(v.len(), self.cfg.layers * n * d, "import V geometry mismatch");
        let mut seq = SeqKv { blocks: Vec::new(), len: 0, hash: ROOT_HASH };
        let bt = self.cfg.block_tokens;
        while seq.len < n {
            let want = &tokens[seq.len..];
            let mut best: Option<(usize, usize)> = None;
            if let Some(cands) = self.children.get(&seq.hash) {
                for &b in cands {
                    let have = &self.meta[b].tokens;
                    let mut m = 0;
                    while m < want.len() && m < have.len() && have[m] == want[m] {
                        m += 1;
                    }
                    let beats = match best {
                        Some((_, bm)) => m > bm,
                        None => m > 0,
                    };
                    if beats {
                        best = Some((b, m));
                    }
                }
            }
            let Some((b, m)) = best else { break };
            self.retain_block(b);
            self.meta[b].hits += 1;
            self.touch(b);
            seq.blocks.push(b);
            for &t in &tokens[seq.len..seq.len + m] {
                seq.hash = chain(seq.hash, t);
            }
            seq.len += m;
            if m < bt {
                break;
            }
        }
        for pos in seq.len..n {
            if let Err(e) = self.append(&mut seq, tokens[pos]) {
                self.release(seq);
                return Err(e);
            }
            for layer in 0..self.cfg.layers {
                let at = (layer * n + pos) * d;
                self.k_row_mut(&seq, layer, pos).copy_from_slice(&k[at..at + d]);
                self.v_row_mut(&seq, layer, pos).copy_from_slice(&v[at..at + d]);
            }
        }
        self.note_used();
        Ok(seq)
    }
}

/// Serial read/write adapter binding one session table to its pool:
/// the [`KvStore`] the paged prefill path decodes through.
pub struct PagedSeq<'a> {
    pub pool: &'a mut BlockPool,
    pub seq: &'a mut SeqKv,
    /// Position capacity (the model's `max_seq`).
    pub cap: usize,
}

impl KvStore for PagedSeq<'_> {
    fn len(&self) -> usize {
        self.seq.len()
    }

    fn reserve(&mut self, token: usize) -> Result<(), KvStoreFull> {
        let pos = self.seq.len();
        if pos >= self.cap {
            return Err(KvStoreFull {
                pos,
                detail: format!("sequence capacity {} reached", self.cap),
            });
        }
        self.pool
            .append(self.seq, token)
            .map_err(|e| KvStoreFull { pos: e.pos(), detail: e.to_string() })
    }

    fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.pool.k_row(self.seq, layer, pos)
    }

    fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        self.pool.v_row(self.seq, layer, pos)
    }

    fn write_row(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.pool.k_row_mut(self.seq, layer, pos)[..k.len()].copy_from_slice(k);
        self.pool.v_row_mut(self.seq, layer, pos)[..v.len()].copy_from_slice(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(bt: usize, blocks: usize) -> BlockPool {
        BlockPool::new(KvPoolConfig { layers: 2, dim: 3, block_tokens: bt, num_blocks: blocks })
    }

    /// Append `tokens` to a fresh session, writing a recognizable value
    /// into every row: k = base + pos, v = -(base + pos).
    fn fill(p: &mut BlockPool, tokens: &[usize], base: f32) -> SeqKv {
        let (mut seq, reused) = p.begin(tokens);
        for i in reused..tokens.len() {
            p.append(&mut seq, tokens[i]).unwrap();
            for layer in 0..p.config().layers {
                let val = base + i as f32;
                p.k_row_mut(&seq, layer, i).fill(val);
                p.v_row_mut(&seq, layer, i).fill(-val);
            }
        }
        seq
    }

    /// The public chain-hash helpers agree with each other and with the
    /// pool's own prefix index: equal prefixes hash equal, divergence at
    /// any position changes every later point.
    #[test]
    fn prefix_chain_helpers_are_consistent() {
        let toks: Vec<usize> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3];
        let points = prefix_chain_points(&toks, 4);
        // Boundaries at 4, 8, and the full length 10.
        assert_eq!(points.len(), 3);
        assert_eq!(points[0], prefix_chain_hash(&toks[..4]));
        assert_eq!(points[1], prefix_chain_hash(&toks[..8]));
        assert_eq!(points[2], prefix_chain_hash(&toks));
        // A short prompt still yields its full-length point.
        assert_eq!(prefix_chain_points(&toks[..2], 4), vec![prefix_chain_hash(&toks[..2])]);
        assert!(prefix_chain_points(&[], 4).is_empty());
        // Divergence at position 1 changes every point.
        let mut forked = toks.clone();
        forked[1] ^= 1;
        for (a, b) in points.iter().zip(prefix_chain_points(&forked, 4)) {
            assert_ne!(*a, b, "diverged prefixes must not collide");
        }
        // Stride 0 is clamped to 1 (a point per token).
        assert_eq!(prefix_chain_points(&toks, 0).len(), toks.len());
    }

    #[test]
    fn append_fills_blocks_exactly() {
        let mut p = pool(4, 4);
        let toks: Vec<usize> = (0..8).collect();
        let seq = fill(&mut p, &toks, 100.0);
        // 8 tokens at block_tokens = 4: exactly two full blocks.
        assert_eq!(seq.blocks().len(), 2);
        assert_eq!(seq.len(), 8);
        assert_eq!(p.stats().used_blocks, 2);
        // The ninth token opens a third block.
        let mut seq = seq;
        p.append(&mut seq, 42).unwrap();
        assert_eq!(seq.blocks().len(), 3);
        p.release(seq);
    }

    #[test]
    fn zero_length_prompt_yields_empty_table() {
        let mut p = pool(4, 2);
        let (seq, reused) = p.begin(&[]);
        assert_eq!(seq.len(), 0);
        assert_eq!(reused, 0);
        assert!(seq.blocks().is_empty());
        p.release(seq);
        assert_eq!(p.stats().used_blocks, 0);
    }

    #[test]
    fn rows_round_trip_through_block_tables() {
        let mut p = pool(4, 4);
        let toks = [9usize, 8, 7, 6, 5];
        let seq = fill(&mut p, &toks, 10.0);
        for i in 0..5 {
            for layer in 0..2 {
                assert!(p.k_row(&seq, layer, i).iter().all(|&x| x == 10.0 + i as f32));
                assert!(p.v_row(&seq, layer, i).iter().all(|&x| x == -(10.0 + i as f32)));
            }
        }
    }

    #[test]
    fn shared_prefix_maps_same_physical_blocks() {
        let mut p = pool(4, 8);
        let prompt: Vec<usize> = (0..8).collect();
        let a = fill(&mut p, &prompt, 0.0);
        let used_after_a = p.stats().used_blocks;
        let (b, reused) = p.begin(&prompt);
        // Matching is capped at len - 1 = 7: block 0 in full, 3 rows of
        // block 1.
        assert_eq!(reused, 7);
        assert_eq!(b.blocks()[0], a.blocks()[0]);
        assert_eq!(b.blocks()[1], a.blocks()[1]);
        // No new physical blocks were consumed by the share.
        assert_eq!(p.stats().used_blocks, used_after_a);
        let s = p.stats();
        // A's begin queried 7 positions (cold), B's queried 7 (all hits).
        assert_eq!(s.prefix_hit_tokens, 7);
        assert_eq!(s.prefix_query_tokens, 14);
        assert!((s.prefix_hit_rate() - 0.5).abs() < 1e-12);
        p.release(a);
        p.release(b);
    }

    #[test]
    fn cow_fork_preserves_the_peer() {
        let mut p = pool(4, 8);
        let prompt: Vec<usize> = vec![1, 2, 3, 4, 5, 6];
        let a = fill(&mut p, &prompt, 50.0);
        let (mut b, reused) = p.begin(&prompt);
        assert_eq!(reused, 5, "matched through block 0 plus one row of block 1");
        assert_eq!(b.blocks()[1], a.blocks()[1], "partial block shared before the fork");
        // B diverges inside the shared partial block: COW fork.
        p.append(&mut b, 999).unwrap();
        assert_eq!(p.stats().cow_copies, 1);
        assert_ne!(b.blocks()[1], a.blocks()[1], "fork gave B a private block");
        for layer in 0..2 {
            p.k_row_mut(&b, layer, 5).fill(777.0);
        }
        // A's rows are untouched; B's copied rows match A's originals.
        for layer in 0..2 {
            assert!(p.k_row(&a, layer, 5).iter().all(|&x| x == 55.0));
            assert!(p.k_row(&b, layer, 5).iter().all(|&x| x == 777.0));
            assert!(p.k_row(&b, layer, 4).iter().all(|&x| x == 54.0), "COW copied shared rows");
        }
        p.release(a);
        p.release(b);
    }

    #[test]
    fn release_drops_refcounts_and_frees_blocks() {
        let mut p = pool(4, 4);
        let prompt: Vec<usize> = (10..18).collect();
        let a = fill(&mut p, &prompt, 0.0);
        let (b, _) = p.begin(&prompt);
        assert_eq!(p.stats().used_blocks, 2);
        // Cancel B: shared blocks stay live via A's references.
        p.release(b);
        assert_eq!(p.stats().used_blocks, 2);
        // Cancel A: blocks park idle (allocatable, still indexed).
        p.release(a);
        let s = p.stats();
        assert_eq!(s.used_blocks, 0);
        assert_eq!(s.idle_blocks, 2);
        assert_eq!(s.free_blocks, 4);
        // A later identical prompt still hits the retained blocks.
        let (c, reused) = p.begin(&prompt);
        assert_eq!(reused, 7);
        p.release(c);
    }

    #[test]
    fn exhaustion_is_a_typed_error_at_the_failing_position() {
        let mut p = pool(2, 2);
        let (mut seq, _) = p.begin(&[]);
        for t in 0..4 {
            p.append(&mut seq, t).unwrap();
        }
        let err = p.append(&mut seq, 4).unwrap_err();
        assert_eq!(err, KvError::Exhausted { pos: 4 });
        assert_eq!(err.pos(), 4);
        // Releasing recovers the pool.
        p.release(seq);
        let (mut seq2, _) = p.begin(&[]);
        p.append(&mut seq2, 9).unwrap();
        p.release(seq2);
    }

    #[test]
    fn eviction_unregisters_the_oldest_idle_block() {
        let mut p = pool(2, 2);
        let a = fill(&mut p, &[1, 2, 3, 4], 0.0);
        p.release(a);
        assert_eq!(p.stats().idle_blocks, 2);
        // A different session must evict both idle blocks.
        let b = fill(&mut p, &[7, 8, 9, 10], 1.0);
        assert_eq!(p.stats().idle_blocks, 0);
        p.release(b);
        // The original prompt no longer matches (its blocks were evicted
        // and unregistered).
        let (c, reused) = p.begin(&[1, 2, 3, 4]);
        assert_eq!(reused, 0);
        p.release(c);
    }

    #[test]
    fn paged_seq_store_reserves_and_writes() {
        let mut p = pool(4, 2);
        let (mut seq, _) = p.begin(&[]);
        {
            let mut store = PagedSeq { pool: &mut p, seq: &mut seq, cap: 6 };
            for t in 0..6usize {
                assert_eq!(store.len(), t);
                store.reserve(t).unwrap();
                store.write_row(0, t, &[t as f32; 3], &[0.5; 3]);
            }
            // Capacity is enforced before pool space.
            let err = store.reserve(6).unwrap_err();
            assert_eq!(err.pos, 6);
            assert!(err.detail.contains("capacity"));
        }
        for t in 0..6 {
            assert!(p.k_row(&seq, 0, t).iter().all(|&x| x == t as f32));
        }
        p.release(seq);
    }

    /// Build the discriminating idle state: two idle blocks where the
    /// *older-queued* one (A) is hotter — one prefix hit, fresher touch —
    /// than the younger-queued one (B). FIFO sacrifices A; LRU and Freq
    /// sacrifice B.
    fn hot_head_idle_pool(policy: EvictPolicyKind) -> BlockPool {
        let mut p = pool(2, 2);
        p.set_policy(policy);
        let a = fill(&mut p, &[1, 2], 0.0);
        let b = fill(&mut p, &[3, 4], 10.0);
        // Re-attach A's block while A still holds it: hits += 1, touch.
        let (s, reused) = p.begin(&[1, 2, 99]);
        assert_eq!(reused, 2);
        p.release(a);
        p.release(s); // A's block idles first...
        p.release(b); // ...then B's: idle order [A, B].
        assert_eq!(p.stats().idle_blocks, 2);
        p
    }

    #[test]
    fn fifo_eviction_sacrifices_the_hot_head_block() {
        let mut p = hot_head_idle_pool(EvictPolicyKind::Fifo);
        let c = fill(&mut p, &[9, 10], 20.0);
        assert_eq!(p.stats().evictions, 1);
        let (s, reused) = p.begin(&[1, 2, 99]);
        assert_eq!(reused, 0, "FIFO threw away the hot prefix block");
        p.release(c);
        p.release(s);
    }

    #[test]
    fn lru_and_freq_eviction_keep_the_hot_block() {
        for policy in [EvictPolicyKind::Lru, EvictPolicyKind::Freq] {
            let mut p = hot_head_idle_pool(policy);
            let c = fill(&mut p, &[9, 10], 20.0);
            assert_eq!(p.stats().evictions, 1);
            let (s, reused) = p.begin(&[1, 2, 99]);
            assert_eq!(reused, 2, "{} evicted the cold block instead", policy.name());
            p.release(c);
            p.release(s);
        }
    }

    #[test]
    fn export_import_round_trips_bitwise() {
        let mut p = pool(4, 8);
        let toks: Vec<usize> = (100..106).collect();
        let seq = fill(&mut p, &toks, 30.0);
        assert_eq!(p.tokens_of(&seq), toks);
        let (k, v) = p.export_kv(&seq);
        let want_k: Vec<Vec<f32>> =
            (0..6).map(|i| p.k_row(&seq, 1, i).to_vec()).collect();
        p.release(seq);
        // Churn the pool until every original block is evicted.
        let filler: Vec<usize> = (500..532).collect();
        let f = fill(&mut p, &filler, 40.0);
        assert!(p.stats().evictions > 0, "filler must evict the released blocks");
        p.release(f);
        let seq2 = p.import_kv(&toks, &k, &v).unwrap();
        assert_eq!(seq2.len(), 6);
        assert_eq!(p.tokens_of(&seq2), toks);
        let (k2, v2) = p.export_kv(&seq2);
        assert_eq!(k, k2, "imported K rows must be bitwise identical");
        assert_eq!(v, v2, "imported V rows must be bitwise identical");
        for (i, row) in want_k.iter().enumerate() {
            assert_eq!(p.k_row(&seq2, 1, i), &row[..]);
        }
        p.release(seq2);
    }

    #[test]
    fn import_reattaches_resident_prefix() {
        let mut p = pool(4, 8);
        let toks: Vec<usize> = (7..15).collect();
        let seq = fill(&mut p, &toks, 0.0);
        let (k, v) = p.export_kv(&seq);
        let original_blocks = seq.blocks().to_vec();
        p.release(seq);
        // Blocks are idle but resident: import matches all 8 positions
        // (no `len - 1` cap — a resume needs no fresh logits).
        let seq2 = p.import_kv(&toks, &k, &v).unwrap();
        assert_eq!(seq2.len(), 8);
        assert_eq!(seq2.blocks(), &original_blocks[..], "reused the resident blocks");
        assert_eq!(p.stats().evictions, 0);
        p.release(seq2);
    }

    #[test]
    fn import_failure_releases_partial_table() {
        let mut p = pool(2, 2);
        let toks: Vec<usize> = (0..6).collect();
        let k = vec![0f32; 2 * 6 * 3];
        let v = vec![0f32; 2 * 6 * 3];
        // 6 tokens need 3 blocks; the pool has 2.
        let err = p.import_kv(&toks, &k, &v).unwrap_err();
        assert_eq!(err, KvError::Exhausted { pos: 4 });
        let s = p.stats();
        assert_eq!(s.used_blocks, 0, "partial import table was released");
        assert_eq!(s.free_blocks, 2);
    }

    #[test]
    fn truncate_releases_whole_blocks_and_rewinds_the_hash() {
        let mut p = pool(4, 4);
        let toks: Vec<usize> = (0..10).collect();
        let mut seq = fill(&mut p, &toks, 0.0);
        assert_eq!(seq.blocks().len(), 3);
        // Cut back to 6 positions: block 2 drops, block 1 keeps rows 4..6.
        p.truncate(&mut seq, 6);
        assert_eq!(seq.len(), 6);
        assert_eq!(seq.blocks().len(), 2);
        assert_eq!(p.tokens_of(&seq), &toks[..6]);
        assert_eq!(p.stats().idle_blocks, 1, "dropped block parks idle");
        // The rewound hash is consistent: appending the same tokens again
        // reproduces the original chain, so an identical 10-token prompt
        // still prefix-matches this session's blocks.
        for t in 6..10 {
            p.append(&mut seq, t).unwrap();
        }
        let (peer, reused) = p.begin(&toks);
        assert_eq!(reused, 9, "re-grown chain is content-addressable");
        p.release(peer);
        p.release(seq);
    }

    #[test]
    fn truncate_past_len_and_to_zero_are_sound() {
        let mut p = pool(4, 4);
        let mut seq = fill(&mut p, &[5, 6, 7], 0.0);
        p.truncate(&mut seq, 3); // no-op: pos == len
        p.truncate(&mut seq, 7); // no-op: pos > len
        assert_eq!(seq.len(), 3);
        assert_eq!(seq.blocks().len(), 1);
        p.truncate(&mut seq, 0);
        assert_eq!(seq.len(), 0);
        assert!(seq.blocks().is_empty());
        assert_eq!(p.stats().used_blocks, 0);
        // The emptied table accepts appends again from position zero.
        p.append(&mut seq, 9).unwrap();
        assert_eq!(p.tokens_of(&seq), &[9]);
        p.release(seq);
    }

    #[test]
    fn truncate_onto_a_shared_partial_block_never_mutates_the_peer() {
        let mut p = pool(4, 8);
        let prompt: Vec<usize> = vec![1, 2, 3, 4, 5, 6, 7];
        let a = fill(&mut p, &prompt, 50.0);
        let (mut b, reused) = p.begin(&prompt);
        assert_eq!(reused, 6, "block 0 in full plus two rows of block 1");
        assert_eq!(b.blocks()[1], a.blocks()[1], "partial block shared");
        // Roll B back *into* the shared partial block, then diverge. The
        // truncate must leave A's token list and rows untouched; the
        // divergent append must COW-fork, not overwrite.
        p.truncate(&mut b, 5);
        assert_eq!(b.len(), 5);
        assert_eq!(b.blocks()[1], a.blocks()[1], "truncate keeps the shared block");
        assert_eq!(p.tokens_of(&a), prompt, "peer's token view intact");
        p.append(&mut b, 999).unwrap();
        assert_eq!(p.stats().cow_copies, 1, "divergence after rollback forks");
        assert_ne!(b.blocks()[1], a.blocks()[1]);
        for layer in 0..2 {
            assert!(p.k_row(&a, layer, 5).iter().all(|&x| x == 55.0), "peer rows intact");
            assert!(p.k_row(&b, layer, 4).iter().all(|&x| x == 54.0), "fork copied kept rows");
        }
        p.release(a);
        p.release(b);
    }

    #[test]
    fn truncate_then_regrow_in_a_sole_owner_block_reuses_the_block() {
        let mut p = pool(4, 4);
        let mut seq = fill(&mut p, &[1, 2, 3, 4, 5, 6], 0.0);
        let block1 = seq.blocks()[1];
        // Rollback mid-block, then append a *different* token: the sole
        // owner truncates the stale token tail in place (no fork, no
        // fresh allocation).
        p.truncate(&mut seq, 5);
        p.append(&mut seq, 77).unwrap();
        assert_eq!(seq.blocks()[1], block1, "sole owner rewrites in place");
        assert_eq!(p.stats().cow_copies, 0);
        assert_eq!(p.tokens_of(&seq), &[1, 2, 3, 4, 5, 77]);
        p.release(seq);
    }

    #[test]
    fn peak_and_utilization_track_usage() {
        let mut p = pool(2, 4);
        let a = fill(&mut p, &[1, 2, 3], 0.0);
        let s = p.stats();
        assert_eq!(s.used_blocks, 2);
        assert!((s.utilization() - 0.5).abs() < 1e-12);
        p.release(a);
        assert_eq!(p.stats().peak_used_blocks, 2);
        assert_eq!(p.stats().used_blocks, 0);
    }
}
