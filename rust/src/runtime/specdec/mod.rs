//! Self-speculative decoding: the compressed-variant draft engine
//! (DESIGN.md §11).
//!
//! The method registry holds dense and compressed (pifa / lowrank /
//! lowrank-s24) variants of the *same* checkpoint — the classic
//! draft/verify pair for free. Each scheduler iteration on a speculative
//! session runs:
//!
//! 1. **draft** — k greedy tokens on the cheap compressed variant here,
//!    against a private paged-KV pool whose lanes mirror the target
//!    backend's lanes 1:1;
//! 2. **verify** — all k+1 positions scored through the dense target
//!    (`DecodeBackend::verify`, a sequential span so the arithmetic is
//!    bitwise-identical to plain decode);
//! 3. **accept** — the longest draft prefix matching the target's greedy
//!    picks, plus the target's one bonus token;
//! 4. **rollback** — rejected positions drop off both pools by block-table
//!    truncation ([`crate::runtime::kvpool::BlockPool::truncate`]).
//!
//! Because acceptance is judged entirely by target logits, the output
//! stream is bitwise-identical to plain greedy dense decode no matter
//! how bad the draft is — the draft quality only moves the speedup.
//!
//! The mirror KV is *self-healing*: every call to [`DraftEngine::draft`]
//! names the owning session, so a lane reused by a new session (or a
//! mirror left stale by a fallback) is released and re-begun from the
//! target's committed prefix — a draft-side prefill. Draft-pool
//! exhaustion surfaces as a typed error the scheduler maps to a plain
//! per-session fallback, never to a target-session failure.

use crate::model::transformer::{KvStoreFull, Transformer};
use crate::runtime::exec::argmax;
use crate::runtime::kvpool::{BlockPool, KvPoolConfig, KvPoolStats, PagedSeq};
use crate::runtime::kvpool::SeqKv;

/// Tuning knobs for speculative decoding.
#[derive(Clone, Debug)]
pub struct SpecConfig {
    /// Draft tokens proposed per iteration (1..=16).
    pub draft_k: usize,
    /// A session whose acceptance rate sits below this floor after
    /// `floor_window` drafted tokens falls back to plain decode for the
    /// rest of its life (the draft is costing more than it saves).
    pub accept_floor: f64,
    /// Drafted tokens observed before the floor is judged.
    pub floor_window: usize,
}

impl Default for SpecConfig {
    fn default() -> Self {
        Self { draft_k: 4, accept_floor: 0.1, floor_window: 64 }
    }
}

/// The draft side of the speculative loop: a compressed model plus its
/// own block pool, with one mirror [`SeqKv`] per target lane.
pub struct DraftEngine {
    model: Transformer,
    pool: BlockPool,
    cfg: SpecConfig,
    /// Mirror block table per target lane (grown on demand).
    seqs: Vec<Option<SeqKv>>,
    /// Session id each mirror belongs to — lane reuse by a new session
    /// invalidates the old mirror.
    owner: Vec<Option<u64>>,
}

impl DraftEngine {
    /// Draft engine whose pool matches a contiguous `lanes × max_seq`
    /// cache of the draft model's geometry (same sizing rule as the
    /// target backend's paged pool).
    pub fn new(model: Transformer, lanes: usize, cfg: SpecConfig) -> Self {
        let pool_cfg = KvPoolConfig::matching_contiguous(
            model.cfg.n_layers,
            model.cfg.dim,
            lanes,
            model.cfg.max_seq,
        );
        Self::with_pool(model, cfg, pool_cfg)
    }

    /// Explicit pool geometry (tests shrink it to force exhaustion).
    pub fn with_pool(model: Transformer, cfg: SpecConfig, pool_cfg: KvPoolConfig) -> Self {
        debug_assert!((1..=16).contains(&cfg.draft_k), "draft_k out of range");
        Self { model, pool: BlockPool::new(pool_cfg), cfg, seqs: Vec::new(), owner: Vec::new() }
    }

    pub fn config(&self) -> &SpecConfig {
        &self.cfg
    }

    pub fn stats(&self) -> KvPoolStats {
        self.pool.stats()
    }

    /// Cached mirror length for a lane (tests).
    pub fn lane_len(&self, lane: usize) -> usize {
        self.seqs.get(lane).and_then(|s| s.as_ref()).map_or(0, |s| s.len())
    }

    fn ensure_lane(&mut self, lane: usize) {
        if lane >= self.seqs.len() {
            self.seqs.resize_with(lane + 1, || None);
            self.owner.resize(lane + 1, None);
        }
    }

    /// Propose `k` greedy draft tokens for session `id` on `lane`, whose
    /// committed target sequence is `seq`. Catches the mirror KV up to
    /// `seq.len() - 1` positions (re-beginning from scratch when the
    /// lane changed owners), feeds the last committed token, then chains
    /// k greedy picks. On pool exhaustion the mirror is released and the
    /// typed error returned — the caller falls back to plain decode; the
    /// target session is untouched.
    pub fn draft(
        &mut self,
        lane: usize,
        id: u64,
        seq: &[usize],
        k: usize,
    ) -> Result<Vec<usize>, KvStoreFull> {
        assert!(!seq.is_empty(), "draft requires a non-empty sequence");
        self.ensure_lane(lane);
        if self.owner[lane] != Some(id) {
            if let Some(old) = self.seqs[lane].take() {
                self.pool.release(old);
            }
            self.owner[lane] = Some(id);
        }
        let mut kv = match self.seqs[lane].take() {
            Some(kv) => kv,
            // Fresh mirror: re-attach whatever prefix is resident in the
            // draft pool (shared system prompts hit here too).
            None => self.pool.begin(&seq[..seq.len() - 1]).0,
        };
        // A mirror longer than the committed prefix (left by a fallback
        // mid-iteration) rolls back before catching up.
        if kv.len() + 1 > seq.len() {
            self.pool.truncate(&mut kv, seq.len() - 1);
        }
        match self.draft_into(&mut kv, seq, k) {
            Ok(drafts) => {
                self.seqs[lane] = Some(kv);
                Ok(drafts)
            }
            Err(e) => {
                self.pool.release(kv);
                self.owner[lane] = None;
                Err(e)
            }
        }
    }

    fn draft_into(
        &mut self,
        kv: &mut SeqKv,
        seq: &[usize],
        k: usize,
    ) -> Result<Vec<usize>, KvStoreFull> {
        let cap = self.model.cfg.max_seq;
        // Catch-up: decode committed tokens the mirror has not cached,
        // stopping one short — the last committed token starts drafting.
        for pos in kv.len()..seq.len() - 1 {
            let mut store = PagedSeq { pool: &mut self.pool, seq: kv, cap };
            self.model.decode_step_kv(seq[pos], &mut store)?;
        }
        let mut drafts = Vec::with_capacity(k);
        let mut next = *seq.last().expect("non-empty sequence");
        for _ in 0..k {
            let mut store = PagedSeq { pool: &mut self.pool, seq: kv, cap };
            let logits = self.model.decode_step_kv(next, &mut store)?;
            next = argmax(logits.row(0));
            drafts.push(next);
        }
        Ok(drafts)
    }

    /// Roll the lane's mirror back to `pos` cached positions (rejected
    /// draft tokens after a verify). A `pos` at or past the mirror
    /// length — the all-accepted case, where the mirror is one position
    /// short — is a no-op; the next draft's catch-up fills the gap.
    pub fn truncate(&mut self, lane: usize, pos: usize) {
        if let Some(kv) = self.seqs.get_mut(lane).and_then(|s| s.as_mut()) {
            self.pool.truncate(kv, pos);
        }
    }

    /// Release a lane's mirror (session finished, cancelled, preempted,
    /// or fallen back to plain decode).
    pub fn release(&mut self, lane: usize) {
        if lane < self.seqs.len() {
            if let Some(kv) = self.seqs[lane].take() {
                self.pool.release(kv);
            }
            self.owner[lane] = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;
    use crate::model::config::ModelConfig;

    fn micro_model(seed: u64) -> Transformer {
        let cfg = ModelConfig {
            vocab: 32,
            dim: 16,
            n_layers: 2,
            n_heads: 2,
            ffn_hidden: 24,
            max_seq: 64,
            ..ModelConfig::tiny_s()
        };
        Transformer::new_random(&cfg, &mut Rng::new(seed))
    }

    /// Greedy reference: the token the draft model itself would decode
    /// next after `seq`, computed through a fresh contiguous cache.
    fn greedy_chain(model: &Transformer, seq: &[usize], k: usize) -> Vec<usize> {
        let mut cache = crate::model::transformer::KvCache::new(&model.cfg);
        let mut logits = None;
        for &t in seq {
            logits = Some(model.decode_step(t, &mut cache));
        }
        let mut out = Vec::new();
        let mut next = argmax(logits.expect("non-empty seq").row(0));
        for _ in 0..k {
            out.push(next);
            if out.len() == k {
                break;
            }
            let l = model.decode_step(next, &mut cache);
            next = argmax(l.row(0));
        }
        out
    }

    #[test]
    fn drafts_match_the_models_own_greedy_chain() {
        let model = micro_model(7);
        let mut eng = DraftEngine::new(model.clone(), 2, SpecConfig::default());
        let seq = vec![3usize, 1, 4, 1, 5];
        let drafts = eng.draft(0, 42, &seq, 4).unwrap();
        assert_eq!(drafts, greedy_chain(&model, &seq, 4));
        // Mirror sits one short of seq end plus the drafts it fed.
        assert_eq!(eng.lane_len(0), seq.len() + 4 - 1);
    }

    #[test]
    fn truncate_then_redraft_is_consistent() {
        let model = micro_model(9);
        let mut eng = DraftEngine::new(model.clone(), 2, SpecConfig::default());
        let mut seq = vec![2usize, 7, 1];
        let drafts = eng.draft(0, 1, &seq, 3).unwrap();
        // Pretend verify accepted one draft plus a bonus token 9.
        seq.push(drafts[0]);
        seq.push(9);
        eng.truncate(0, seq.len() - 1);
        let redraft = eng.draft(0, 1, &seq, 3).unwrap();
        assert_eq!(redraft, greedy_chain(&model, &seq, 3));
    }

    #[test]
    fn lane_reuse_by_a_new_session_resets_the_mirror() {
        let model = micro_model(11);
        let mut eng = DraftEngine::new(model.clone(), 1, SpecConfig::default());
        eng.draft(0, 1, &[1, 2, 3], 2).unwrap();
        // Same lane, different session id, unrelated sequence.
        let seq = vec![9usize, 8, 7, 6];
        let drafts = eng.draft(0, 2, &seq, 2).unwrap();
        assert_eq!(drafts, greedy_chain(&model, &seq, 2));
    }

    #[test]
    fn exhaustion_is_typed_and_releases_the_mirror() {
        let model = micro_model(13);
        // One block of 4 tokens: a 5-position draft run must exhaust.
        let pool_cfg = KvPoolConfig {
            layers: model.cfg.n_layers,
            dim: model.cfg.dim,
            block_tokens: 4,
            num_blocks: 1,
        };
        let mut eng = DraftEngine::with_pool(model, SpecConfig::default(), pool_cfg);
        let err = eng.draft(0, 1, &[1, 2, 3, 4, 5], 4).unwrap_err();
        assert_eq!(err.pos, 4, "failed exactly at the first unfundable position");
        assert_eq!(eng.lane_len(0), 0, "failed mirror was released");
        assert_eq!(eng.stats().used_blocks, 0, "no leaked draft blocks");
    }
}
