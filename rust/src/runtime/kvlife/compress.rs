//! PIFA compression of cold spilled KV matrices (DESIGN.md §10).
//!
//! A spilled session's per-layer K (or V) rows form a `len × dim`
//! matrix — the same shape family the paper's pivoting factorization
//! targets for weights. Compressing cold KV turns host-arena capacity
//! into a rank knob: at rank `r = rank_frac · min(len, dim)` the
//! factors are exact whenever the matrix's true rank is at most `r`
//! and lossy above it. The serving bench measures the resulting PPL
//! drift (`kv_ppl_drift`) and the capacity gain
//! (`kv_compression_ratio`); the bitwise differential suite only ever
//! sees the raw representation.

use crate::linalg::Mat;
use crate::pifa::{pivoting_factorization, PifaLayer, PivotStrategy};

/// One layer's K or V rows, either raw or PIFA-factorized.
pub struct CompressedKv {
    rows: usize,
    dim: usize,
    repr: Repr,
}

enum Repr {
    Raw(Vec<f32>),
    Pifa(PifaLayer<f32>),
}

impl CompressedKv {
    /// Store `rows × dim` row-major `data` verbatim (spill without
    /// compression — the bitwise-exact path).
    pub fn raw(rows: usize, dim: usize, data: Vec<f32>) -> Self {
        debug_assert_eq!(data.len(), rows * dim, "raw KV geometry mismatch");
        Self { rows, dim, repr: Repr::Raw(data) }
    }

    /// Factorize `rows × dim` row-major `data` at
    /// `r = rank_frac · min(rows, dim)`. Falls back to raw storage when
    /// the factorization cannot win: degenerate shapes, a rank so close
    /// to full that the factors outweigh the matrix, or a matrix the
    /// pivot search rejects.
    pub fn compress(rows: usize, dim: usize, data: &[f32], rank_frac: f64) -> Self {
        debug_assert_eq!(data.len(), rows * dim, "KV geometry mismatch");
        if rows >= 2 && dim >= 2 {
            let full = rows.min(dim);
            let r = ((full as f64 * rank_frac).round() as usize).clamp(1, full);
            let w = Mat::from_vec(rows, dim, data.to_vec());
            if let Ok(layer) = pivoting_factorization(&w, r, PivotStrategy::QrColumnPivot) {
                if layer.param_count() < rows * dim {
                    return Self { rows, dim, repr: Repr::Pifa(layer) };
                }
            }
        }
        Self::raw(rows, dim, data.to_vec())
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// True when the PIFA factors are stored instead of the raw rows.
    pub fn is_compressed(&self) -> bool {
        matches!(self.repr, Repr::Pifa(_))
    }

    /// f32 values actually stored (the arena's byte accounting).
    pub fn stored_f32s(&self) -> usize {
        match &self.repr {
            Repr::Raw(d) => d.len(),
            Repr::Pifa(l) => l.param_count(),
        }
    }

    /// Materialize the `rows × dim` row-major matrix: exact for raw
    /// storage and for factorizations at or above the true rank.
    pub fn decompress(&self) -> Vec<f32> {
        match &self.repr {
            Repr::Raw(d) => d.clone(),
            Repr::Pifa(l) => l.reconstruct().into_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic rank-2 matrix: row i = a_i * u + b_i * w.
    fn low_rank(rows: usize, dim: usize) -> Vec<f32> {
        let mut data = vec![0f32; rows * dim];
        for i in 0..rows {
            let (a, b) = (1.0 + i as f32, 0.5 * i as f32 - 1.0);
            for j in 0..dim {
                let (u, w) = ((j as f32).sin(), 0.25 * j as f32 + 1.0);
                data[i * dim + j] = a * u + b * w;
            }
        }
        data
    }

    #[test]
    fn raw_round_trips_bitwise() {
        let data: Vec<f32> = (0..24).map(|x| x as f32 * 0.5).collect();
        let c = CompressedKv::raw(4, 6, data.clone());
        assert!(!c.is_compressed());
        assert_eq!(c.stored_f32s(), 24);
        assert_eq!(c.decompress(), data);
    }

    #[test]
    fn low_rank_kv_compresses_losslessly() {
        let (rows, dim) = (12, 8);
        let data = low_rank(rows, dim);
        let c = CompressedKv::compress(rows, dim, &data, 0.5);
        assert!(c.is_compressed(), "rank-2 rows must factorize at r = 4");
        assert!(c.stored_f32s() < rows * dim, "factors must beat raw storage");
        let back = c.decompress();
        let err = data
            .iter()
            .zip(&back)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(err < 1e-3, "true rank below r must reconstruct exactly (err {err})");
    }

    #[test]
    fn degenerate_shapes_fall_back_to_raw() {
        let c = CompressedKv::compress(1, 6, &[1.0; 6], 0.5);
        assert!(!c.is_compressed());
        assert_eq!(c.decompress(), vec![1.0; 6]);
        // Full-rank tiny matrix at rank_frac 1.0: factors cannot win.
        let data = vec![3.0, 1.0, 2.0, 7.0];
        let c = CompressedKv::compress(2, 2, &data, 1.0);
        assert!(!c.is_compressed());
        assert_eq!(c.decompress(), data);
    }
}
