//! KV lifecycle subsystem (DESIGN.md §10): what happens to a block
//! after its first write.
//!
//! The pool (§8) handles *residency* — allocation, refcounts,
//! copy-on-write. This module owns everything after that:
//!
//! * [`policy`] — pluggable idle-block eviction (FIFO / LRU /
//!   frequency), consulted by [`crate::runtime::kvpool::BlockPool`]
//!   when the free list is empty (`pifa serve --kv-evict`).
//! * [`arena`] — the host-side [`SpillArena`]: a preempted session's KV
//!   rows leave the pool and wait, ticket-keyed, for resume.
//! * [`compress`] — opt-in PIFA factorization of cold spilled K/V
//!   matrices — the paper's compact meta low-rank representation
//!   applied to serving state instead of weights.

pub mod arena;
pub mod compress;
pub mod policy;

pub use arena::{SpillArena, SpillArenaStats, SpilledKv};
pub use compress::CompressedKv;
pub use policy::EvictPolicyKind;
