//! Host-side spill arena for preempted sessions (DESIGN.md §10).
//!
//! When the scheduler preempts a low-priority session, its block table
//! leaves the pool entirely: the K/V rows are exported into host
//! buffers (optionally PIFA-compressed, see [`super::compress`]) and
//! parked here under a resume ticket. Resume re-imports the rows
//! through the pool's content-addressed path — any prefix still
//! resident re-attaches bitwise-identically, the rest is rewritten
//! from the arena copy.

use crate::runtime::kvlife::compress::CompressedKv;
use std::collections::HashMap;

/// A spilled session: the tokens whose K/V rows are stored, plus one
/// [`CompressedKv`] per layer for each of K and V
/// (`tokens.len() × dim` matrices).
pub struct SpilledKv {
    pub tokens: Vec<usize>,
    pub k: Vec<CompressedKv>,
    pub v: Vec<CompressedKv>,
}

impl SpilledKv {
    /// Materialize the layer-major contiguous K and V buffers the
    /// pool's import path expects.
    pub fn materialize(&self) -> (Vec<f32>, Vec<f32>) {
        let mut k = Vec::new();
        let mut v = Vec::new();
        for c in &self.k {
            k.extend_from_slice(&c.decompress());
        }
        for c in &self.v {
            v.extend_from_slice(&c.decompress());
        }
        (k, v)
    }
}

/// Cumulative arena counters (monotone; absorbed into `ServeMetrics`
/// at server shutdown).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillArenaStats {
    pub spills: u64,
    pub resumes: u64,
    /// Tickets discarded because the session terminated while spilled.
    pub dropped: u64,
    /// Bytes the spilled rows would occupy uncompressed.
    pub raw_bytes: u64,
    /// Bytes actually stored (== `raw_bytes` with compression off).
    pub stored_bytes: u64,
}

impl SpillArenaStats {
    /// Capacity gain of compression (raw / stored); 1.0 before any
    /// spill.
    pub fn compression_ratio(&self) -> f64 {
        if self.stored_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.stored_bytes as f64
        }
    }
}

/// Ticket-keyed store of spilled sessions.
#[derive(Default)]
pub struct SpillArena {
    next_ticket: u64,
    entries: HashMap<u64, SpilledKv>,
    stats: SpillArenaStats,
}

impl SpillArena {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> SpillArenaStats {
        self.stats
    }

    /// Store a spilled session; returns its resume ticket.
    pub fn insert(&mut self, spilled: SpilledKv) -> u64 {
        let mut raw = 0usize;
        let mut stored = 0usize;
        for c in spilled.k.iter().chain(spilled.v.iter()) {
            raw += c.rows() * c.dim();
            stored += c.stored_f32s();
        }
        self.stats.spills += 1;
        self.stats.raw_bytes += (raw * std::mem::size_of::<f32>()) as u64;
        self.stats.stored_bytes += (stored * std::mem::size_of::<f32>()) as u64;
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        self.entries.insert(ticket, spilled);
        ticket
    }

    /// Borrow a ticket's entry (capacity pre-checks before committing
    /// to a resume).
    pub fn get(&self, ticket: u64) -> Option<&SpilledKv> {
        self.entries.get(&ticket)
    }

    /// Remove and return a ticket's entry for resume.
    pub fn take(&mut self, ticket: u64) -> Option<SpilledKv> {
        let entry = self.entries.remove(&ticket);
        if entry.is_some() {
            self.stats.resumes += 1;
        }
        entry
    }

    /// Discard a ticket (the session reached a terminal state while
    /// spilled). Returns whether the ticket existed.
    pub fn drop_ticket(&mut self, ticket: u64) -> bool {
        let existed = self.entries.remove(&ticket).is_some();
        if existed {
            self.stats.dropped += 1;
        }
        existed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tokens: Vec<usize>, dim: usize) -> SpilledKv {
        let rows = tokens.len();
        let data: Vec<f32> = (0..rows * dim).map(|x| x as f32).collect();
        SpilledKv {
            tokens,
            k: vec![CompressedKv::raw(rows, dim, data.clone())],
            v: vec![CompressedKv::raw(rows, dim, data)],
        }
    }

    #[test]
    fn insert_take_round_trips_and_counts() {
        let mut a = SpillArena::new();
        assert!(a.is_empty());
        let t0 = a.insert(entry(vec![1, 2, 3], 4));
        let t1 = a.insert(entry(vec![9], 4));
        assert_ne!(t0, t1);
        assert_eq!(a.len(), 2);
        let s = a.stats();
        assert_eq!(s.spills, 2);
        // (3 + 1) rows x dim 4 x (K + V) x 4 bytes.
        assert_eq!(s.raw_bytes, (3 + 1) * 4 * 2 * 4);
        assert_eq!(s.stored_bytes, s.raw_bytes, "raw storage stores every byte");
        assert!((s.compression_ratio() - 1.0).abs() < 1e-12);

        let got = a.take(t0).expect("ticket resolves");
        assert_eq!(got.tokens, vec![1, 2, 3]);
        let (k, v) = got.materialize();
        assert_eq!(k.len(), 12);
        assert_eq!(k, v);
        assert_eq!(a.stats().resumes, 1);
        assert!(a.take(t0).is_none(), "tickets are single-use");
    }

    #[test]
    fn drop_ticket_discards_without_a_resume() {
        let mut a = SpillArena::new();
        let t = a.insert(entry(vec![5, 6], 2));
        assert!(a.get(t).is_some());
        assert!(a.drop_ticket(t));
        assert!(!a.drop_ticket(t));
        let s = a.stats();
        assert_eq!((s.spills, s.resumes, s.dropped), (1, 0, 1));
        assert!(a.is_empty());
    }

    #[test]
    fn compressed_entries_store_fewer_bytes() {
        let (rows, dim) = (12, 8);
        // Rank-1 rows: i-th row = (i+1) * ones.
        let mut data = vec![0f32; rows * dim];
        for i in 0..rows {
            for j in 0..dim {
                data[i * dim + j] = (i + 1) as f32;
            }
        }
        let mut a = SpillArena::new();
        a.insert(SpilledKv {
            tokens: (0..rows).collect(),
            k: vec![CompressedKv::compress(rows, dim, &data, 0.5)],
            v: vec![CompressedKv::compress(rows, dim, &data, 0.5)],
        });
        let s = a.stats();
        assert!(s.stored_bytes < s.raw_bytes, "rank-1 KV must compress");
        assert!(s.compression_ratio() > 1.0);
    }
}
