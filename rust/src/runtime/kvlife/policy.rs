//! Pluggable idle-block eviction policies (DESIGN.md §10).
//!
//! When [`crate::runtime::kvpool::BlockPool`]'s free list is empty, an
//! allocation must sacrifice one idle (refs == 0, still-indexed) block.
//! Which one matters: the pool's release path parks a finished
//! session's blocks head-first, so insertion-order eviction throws away
//! the *hot shared-prefix head blocks* first — exactly the rows
//! repeated-fleet traffic would re-attach. The policy sees per-block
//! touch recency and prefix-hit counts and picks the victim.

/// Which idle block the pool sacrifices when the free list is empty.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EvictPolicyKind {
    /// Insertion order: the block that went idle first. Bit-identical
    /// to the pre-lifecycle pool behavior.
    #[default]
    Fifo,
    /// Least recently touched (allocation, prefix re-attach, append).
    Lru,
    /// Fewest prefix-cache hits; ties fall back to least recently
    /// touched.
    Freq,
}

impl EvictPolicyKind {
    /// Parse a `--kv-evict` flag value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fifo" => Some(Self::Fifo),
            "lru" => Some(Self::Lru),
            "freq" | "frequency" => Some(Self::Freq),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Fifo => "fifo",
            Self::Lru => "lru",
            Self::Freq => "freq",
        }
    }

    /// Pick the victim among idle candidates, given `(last_touch,
    /// hits)` per candidate in idle-queue (insertion) order. Returns an
    /// index into `candidates`. Panics on an empty list — the pool only
    /// asks when something is evictable.
    pub fn pick(self, candidates: &[(u64, u64)]) -> usize {
        assert!(!candidates.is_empty(), "eviction with no idle candidates");
        match self {
            Self::Fifo => 0,
            Self::Lru => {
                let mut best = 0;
                for (i, c) in candidates.iter().enumerate().skip(1) {
                    if c.0 < candidates[best].0 {
                        best = i;
                    }
                }
                best
            }
            Self::Freq => {
                let mut best = 0;
                for (i, c) in candidates.iter().enumerate().skip(1) {
                    if (c.1, c.0) < (candidates[best].1, candidates[best].0) {
                        best = i;
                    }
                }
                best
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_round_trip() {
        for kind in [EvictPolicyKind::Fifo, EvictPolicyKind::Lru, EvictPolicyKind::Freq] {
            assert_eq!(EvictPolicyKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(EvictPolicyKind::parse("frequency"), Some(EvictPolicyKind::Freq));
        assert_eq!(EvictPolicyKind::parse("mru"), None);
        assert_eq!(EvictPolicyKind::default(), EvictPolicyKind::Fifo);
    }

    #[test]
    fn fifo_ignores_metadata_and_takes_the_front() {
        let cands = [(9, 9), (1, 0), (5, 3)];
        assert_eq!(EvictPolicyKind::Fifo.pick(&cands), 0);
    }

    #[test]
    fn lru_takes_the_stalest_touch() {
        let cands = [(9, 0), (1, 7), (5, 3)];
        assert_eq!(EvictPolicyKind::Lru.pick(&cands), 1);
    }

    #[test]
    fn freq_takes_fewest_hits_then_stalest() {
        let cands = [(9, 2), (1, 2), (5, 0)];
        assert_eq!(EvictPolicyKind::Freq.pick(&cands), 2);
        // Tie on hits: the staler touch loses.
        let tied = [(9, 1), (1, 1)];
        assert_eq!(EvictPolicyKind::Freq.pick(&tied), 1);
    }
}
