//! PJRT runtime — loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Python never runs here: the Rust binary is self-contained once
//! `make artifacts` has populated `artifacts/`.
//!
//! * [`manifest`] — parses `artifacts/manifest.txt` (artifact index,
//!   canonical parameter order, input shapes).
//! * [`loader`] — PJRT client + HLO-text compile cache.
//! * [`exec`] — `ModelRunner`: binds a checkpointed
//!   [`crate::model::Transformer`] to an artifact's parameter order and
//!   drives prefill / KV-cache decode.
//! * [`kernels`] — structure-aware decode fast paths for the Rust-native
//!   execution layer: the persistent kernel thread pool, batch-≤-4 GEMV,
//!   the fused PIFA apply (DESIGN.md §7), and the paged-KV gather views
//!   (§8).
//! * [`kvpool`] — the paged KV-cache block pool: ref-counted fixed-size
//!   blocks, copy-on-write prefix sharing, per-session block tables
//!   (DESIGN.md §8).
//! * [`kvlife`] — the KV lifecycle layer above the pool: pluggable
//!   idle-block eviction policies, the host-side spill arena for
//!   preempted sessions, and PIFA compression of cold spilled blocks
//!   (DESIGN.md §10).
//! * [`specdec`] — self-speculative decoding: the compressed-variant
//!   [`specdec::DraftEngine`] that proposes k greedy tokens per
//!   iteration against its own paged pool, verified and rolled back by
//!   the serving coordinator (DESIGN.md §11).

pub mod exec;
pub mod kernels;
pub mod kvlife;
pub mod kvpool;
pub mod loader;
pub mod manifest;
pub mod specdec;

pub use exec::{weights_to_literals, LaneKv, ModelRunner};
pub use kvlife::{CompressedKv, EvictPolicyKind, SpillArena, SpillArenaStats, SpilledKv};
pub use kvpool::{BlockPool, KvPoolConfig, KvPoolStats, SeqKv};
pub use specdec::{DraftEngine, SpecConfig};
pub use loader::Engine;
pub use manifest::{ArtifactKind, ArtifactSpec, Manifest, TensorSpec};
