//! Parser for `artifacts/manifest.txt` (written by `python/compile/aot.py`).
//!
//! Line-oriented grammar (no JSON dependency offline):
//!
//! ```text
//! artifact <name>
//! model <preset> vocab <v> dim <d> layers <l> heads <h> ffn <f> maxseq <s>
//! flavour <dense|lowrank|pifa> density <rho>
//! phase <prefill|decode> batch <b> seq <t>
//! param <name> <f32|i32> <dims...>          (repeated, canonical order)
//! input <name> <f32|i32> <dims...>          (repeated, after params)
//! end
//! ```
//! or, for layer microbenches:
//! ```text
//! artifact <name>
//! layerbench <kind> d <d> tokens <t> density <rho>
//! input ...
//! end
//! ```

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One named tensor (parameter or input).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    /// "f32" or "i32".
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// What kind of computation an artifact holds.
#[derive(Clone, Debug, PartialEq)]
pub enum ArtifactKind {
    Model {
        preset: String,
        vocab: usize,
        dim: usize,
        layers: usize,
        heads: usize,
        ffn: usize,
        max_seq: usize,
        flavour: String,
        density: f64,
        phase: String,
        batch: usize,
        seq: usize,
    },
    LayerBench {
        kind: String,
        d: usize,
        tokens: usize,
        density: f64,
    },
}

impl ArtifactKind {
    /// Validate that a model compressed with pipeline-`flavour`-shaped
    /// output at `density` can be bound to this artifact (the lowering in
    /// `python/compile/aot.py` fixes both per artifact). `flavour` is
    /// `PipelineSpec::artifact_flavour()`; density is compared with a
    /// small tolerance because ranks are rounded per module.
    pub fn validate_provenance(&self, flavour: &str, density: f64) -> Result<()> {
        match self {
            ArtifactKind::Model { flavour: af, density: ad, .. } => {
                if af != flavour {
                    bail!(
                        "artifact flavour '{af}' incompatible with pipeline output '{flavour}'"
                    );
                }
                // Dense artifacts carry no density constraint.
                if af != "dense" && (ad - density).abs() > 0.02 {
                    bail!(
                        "artifact lowered for density {ad} but pipeline produced {density}"
                    );
                }
                Ok(())
            }
            ArtifactKind::LayerBench { .. } => {
                bail!("layer-bench artifacts do not serve models")
            }
        }
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    /// Model parameters in canonical feed order (empty for layer benches).
    pub params: Vec<TensorSpec>,
    /// Non-parameter inputs, fed after the params.
    pub inputs: Vec<TensorSpec>,
    /// Path to the `.hlo.txt`.
    pub hlo_path: PathBuf,
}

/// The parsed manifest.
pub struct Manifest {
    pub artifacts: HashMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

fn parse_tensor(rest: &[&str]) -> Result<TensorSpec> {
    if rest.len() < 2 {
        bail!("tensor line too short: {rest:?}");
    }
    let name = rest[0].to_string();
    let dtype = rest[1].to_string();
    if dtype != "f32" && dtype != "i32" {
        bail!("unknown dtype {dtype}");
    }
    let dims = rest[2..]
        .iter()
        .map(|s| s.parse::<usize>().context("bad dim"))
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSpec { name, dtype, dims })
}

impl Manifest {
    /// Load and parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut artifacts = HashMap::new();
        let mut cur: Option<ArtifactSpec> = None;
        let mut model_head: Option<(String, usize, usize, usize, usize, usize, usize)> = None;
        let mut flavour: Option<(String, f64)> = None;

        for (lineno, line) in text.lines().enumerate() {
            let toks: Vec<&str> = line.split_whitespace().collect();
            if toks.is_empty() {
                continue;
            }
            let ctx = || format!("manifest line {}: {line}", lineno + 1);
            match toks[0] {
                "artifact" => {
                    if cur.is_some() {
                        bail!("{}: nested artifact", ctx());
                    }
                    let name = toks[1].to_string();
                    cur = Some(ArtifactSpec {
                        hlo_path: dir.join(format!("{name}.hlo.txt")),
                        name,
                        kind: ArtifactKind::LayerBench {
                            kind: String::new(),
                            d: 0,
                            tokens: 0,
                            density: 0.0,
                        },
                        params: Vec::new(),
                        inputs: Vec::new(),
                    });
                    model_head = None;
                    flavour = None;
                }
                "model" => {
                    model_head = Some((
                        toks[1].to_string(),
                        toks[3].parse()?,
                        toks[5].parse()?,
                        toks[7].parse()?,
                        toks[9].parse()?,
                        toks[11].parse()?,
                        toks[13].parse()?,
                    ));
                }
                "flavour" => {
                    flavour = Some((toks[1].to_string(), toks[3].parse()?));
                }
                "phase" => {
                    let (preset, vocab, dim, layers, heads, ffn, max_seq) =
                        model_head.clone().with_context(ctx)?;
                    let (fl, rho) = flavour.clone().with_context(ctx)?;
                    let a = cur.as_mut().with_context(ctx)?;
                    a.kind = ArtifactKind::Model {
                        preset,
                        vocab,
                        dim,
                        layers,
                        heads,
                        ffn,
                        max_seq,
                        flavour: fl,
                        density: rho,
                        phase: toks[1].to_string(),
                        batch: toks[3].parse()?,
                        seq: toks[5].parse()?,
                    };
                }
                "layerbench" => {
                    let a = cur.as_mut().with_context(ctx)?;
                    a.kind = ArtifactKind::LayerBench {
                        kind: toks[1].to_string(),
                        d: toks[3].parse()?,
                        tokens: toks[5].parse()?,
                        density: toks[7].parse()?,
                    };
                }
                "param" => {
                    cur.as_mut().with_context(ctx)?.params.push(parse_tensor(&toks[1..])?);
                }
                "input" => {
                    cur.as_mut().with_context(ctx)?.inputs.push(parse_tensor(&toks[1..])?);
                }
                "end" => {
                    let a = cur.take().with_context(ctx)?;
                    artifacts.insert(a.name.clone(), a);
                }
                other => bail!("{}: unknown directive {other}", ctx()),
            }
        }
        if cur.is_some() {
            bail!("manifest: unterminated artifact block");
        }
        Ok(Self { artifacts, dir: dir.to_path_buf() })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest ({} entries)", self.artifacts.len()))
    }

    /// All layer-bench artifacts, sorted by name.
    pub fn layer_benches(&self) -> Vec<&ArtifactSpec> {
        let mut v: Vec<_> = self
            .artifacts
            .values()
            .filter(|a| matches!(a.kind, ArtifactKind::LayerBench { .. }))
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact tiny-s_pifa55_decode_b1
model tiny-s vocab 512 dim 64 layers 2 heads 4 ffn 128 maxseq 128
flavour pifa density 0.55
phase decode batch 1 seq 1
param embed f32 512 64
param head f32 512 64
param final_norm f32 64
param l0.q.w_p f32 24 64
param l0.q.inv_perm i32 64
input kv_k f32 2 1 128 64
input tokens i32 1
input pos i32
end
artifact layer_dense_d256_t256
layerbench dense d 256 tokens 256 density 0.0
input x f32 256 256
input w f32 256 256
end
";

    #[test]
    fn parses_model_artifact() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let a = m.get("tiny-s_pifa55_decode_b1").unwrap();
        match &a.kind {
            ArtifactKind::Model { preset, dim, flavour, phase, batch, .. } => {
                assert_eq!(preset, "tiny-s");
                assert_eq!(*dim, 64);
                assert_eq!(flavour, "pifa");
                assert_eq!(phase, "decode");
                assert_eq!(*batch, 1);
            }
            _ => panic!("wrong kind"),
        }
        assert_eq!(a.params.len(), 5);
        assert_eq!(a.params[3].name, "l0.q.w_p");
        assert_eq!(a.params[4].dtype, "i32");
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[2].dims.len(), 0); // scalar pos
        assert!(a.hlo_path.ends_with("tiny-s_pifa55_decode_b1.hlo.txt"));
    }

    #[test]
    fn parses_layer_bench() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let benches = m.layer_benches();
        assert_eq!(benches.len(), 1);
        match &benches[0].kind {
            ArtifactKind::LayerBench { kind, d, tokens, .. } => {
                assert_eq!(kind, "dense");
                assert_eq!(*d, 256);
                assert_eq!(*tokens, 256);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn element_count() {
        let t = TensorSpec { name: "x".into(), dtype: "f32".into(), dims: vec![3, 4] };
        assert_eq!(t.element_count(), 12);
        let s = TensorSpec { name: "pos".into(), dtype: "i32".into(), dims: vec![] };
        assert_eq!(s.element_count(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus line\n", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("artifact a\nparam x f99 3\nend\n", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("artifact a\n", Path::new("/tmp")).is_err());
    }

    #[test]
    fn missing_artifact_lookup_fails() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn provenance_validation() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        let kind = &m.get("tiny-s_pifa55_decode_b1").unwrap().kind;
        // Matching flavour + density passes (within rank-rounding slack).
        assert!(kind.validate_provenance("pifa", 0.55).is_ok());
        assert!(kind.validate_provenance("pifa", 0.56).is_ok());
        // Wrong flavour or far-off density fails.
        assert!(kind.validate_provenance("lowrank", 0.55).is_err());
        assert!(kind.validate_provenance("pifa", 0.8).is_err());
        // Layer benches never serve models.
        let lb = &m.get("layer_dense_d256_t256").unwrap().kind;
        assert!(lb.validate_provenance("pifa", 0.55).is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
        }
    }
}
