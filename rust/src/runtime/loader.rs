//! PJRT client + artifact compile cache.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `compile`) following /opt/xla-example/load_hlo. Compiled executables
//! are cached per artifact name — compilation is the expensive step and the
//! coordinator reuses one executable across all requests.

use super::manifest::{ArtifactSpec, Manifest};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// PJRT engine: client + compile cache + manifest.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU PJRT engine over an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by name.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self.manifest.get(name)?.clone();
            let exe = self.compile_spec(&spec)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).unwrap())
    }

    fn compile_spec(&self, spec: &ArtifactSpec) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = spec
            .hlo_path
            .to_str()
            .context("artifact path not utf-8")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parse HLO text {path_str}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("PJRT compile {}", spec.name))
    }

    /// Execute an artifact with positional literals; returns the flattened
    /// tuple elements (aot.py lowers with return_tuple=True).
    pub fn run(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("execute {name}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {name}"))?;
        out.to_tuple().context("untuple result")
    }

    /// Execute with pre-staged device buffers (the serving fast path:
    /// weights stay resident on the device across calls — EXPERIMENTS.md
    /// §Perf). Returns the flattened tuple elements as host literals.
    pub fn run_b(&mut self, name: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .with_context(|| format!("execute_b {name}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {name}"))?;
        out.to_tuple().context("untuple result")
    }

    /// Stage a host literal onto the device.
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .context("buffer_from_host_literal")
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

/// Build an f32 literal from a row-major matrix.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.is_empty() {
        // Scalar: reshape to rank-0.
        return lit.reshape(&[]).context("reshape scalar literal");
    }
    let d: Vec<i64> = dims.iter().map(|&v| v as i64).collect();
    lit.reshape(&d).context("reshape literal")
}

/// Build an i32 literal.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.is_empty() {
        return lit.reshape(&[]).context("reshape scalar literal");
    }
    let d: Vec<i64> = dims.iter().map(|&v| v as i64).collect();
    lit.reshape(&d).context("reshape literal")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifact_dir().join("manifest.txt").exists()
    }

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let s = literal_i32(&[7], &[]).unwrap();
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn engine_compiles_and_runs_layer_bench() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut eng = Engine::new(&artifact_dir()).unwrap();
        assert!(eng.platform().to_lowercase().contains("cpu") || !eng.platform().is_empty());
        // Find the smallest dense layer bench and run an identity check.
        let name = "layer_dense_d256_t256";
        if eng.manifest.get(name).is_err() {
            return;
        }
        let d = 256;
        let t = 256;
        // x = I (padded), w = I  ->  y = x @ w^T = x.
        let mut x = vec![0f32; t * d];
        for i in 0..t.min(d) {
            x[i * d + i] = 1.0;
        }
        let mut w = vec![0f32; d * d];
        for i in 0..d {
            w[i * d + i] = 1.0;
        }
        let args = vec![
            literal_f32(&x, &[t, d]).unwrap(),
            literal_f32(&w, &[d, d]).unwrap(),
        ];
        let out = eng.run(name, &args).unwrap();
        assert_eq!(out.len(), 1);
        let y = out[0].to_vec::<f32>().unwrap();
        assert_eq!(y.len(), t * d);
        assert_eq!(y[0], 1.0);
        assert_eq!(y[1], 0.0);
        assert_eq!(eng.cached(), 1);
        // Second run hits the cache.
        let _ = eng.run(name, &args).unwrap();
        assert_eq!(eng.cached(), 1);
    }
}
