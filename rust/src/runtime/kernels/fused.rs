//! Fused PIFA decode apply.
//!
//! The generic `PifaLayer::apply_rows_unfused` runs two library GEMMs and
//! then scatters, allocating two intermediate `Mat`s (`Y_p`, `Y_np`) and
//! touching the output twice. At decode batch sizes that overhead is the
//! same order as the math. The fused kernel makes one pass:
//!
//! ```text
//! phase 1:  y_p[k]            = <w_p[k], x>      and   Y[pivot[k]]   = y_p[k]
//! phase 2:  Y[non_pivot[k']]  = <c[k'], y_p>
//! ```
//!
//! The only scratch is the `b x r` `y_p` buffer (needed by phase 2 — it
//! *is* the PIFA trick: non-pivot rows are linear combinations of pivot
//! outputs). Both phases chunk their long axis (`r`, then `m - r`)
//! across the shared pool; phase 2 starts only after phase 1's scope
//! completes, which is exactly the data dependency.

use super::gemv::dot;
use super::pool::SendPtr;
use crate::linalg::{Mat, Scalar};
use crate::pifa::PifaLayer;

/// Transformer-layout fused apply: `X (b x n) -> Y = X W'^T (b x m)`.
/// Works for any batch; the dispatch in [`PifaLayer::apply_rows`] uses it
/// for decode batches (`b <= DECODE_BATCH_MAX`). Allocates the output —
/// the steady-state decode loop should hold a reusable output and call
/// [`pifa_apply_rows_fused_into`] instead.
pub fn pifa_apply_rows_fused<T: Scalar>(layer: &PifaLayer<T>, x: &Mat<T>) -> Mat<T> {
    let mut y = Mat::zeros(x.rows(), layer.m);
    pifa_apply_rows_fused_into(layer, x, &mut y);
    y
}

/// [`pifa_apply_rows_fused`] with a caller-owned output (`y` must be
/// `b x m`). The `b x r` `y_p` buffer comes from the per-thread scratch
/// (`Scalar::with_scratch`), so steady-state decode makes zero transient
/// heap allocations; every output element is written (pivots by phase 1,
/// non-pivots by phase 2), so stale contents of `y` never leak through.
pub fn pifa_apply_rows_fused_into<T: Scalar>(layer: &PifaLayer<T>, x: &Mat<T>, y: &mut Mat<T>) {
    assert_eq!(x.cols(), layer.n, "pifa_apply_rows_fused: input dim mismatch");
    let b = x.rows();
    let m = layer.m;
    let r = layer.rank();
    let n = layer.n;
    assert_eq!(y.shape(), (b, m), "pifa_apply_rows_fused_into: output shape mismatch");
    if b == 0 || m == 0 {
        return;
    }
    if r == 0 {
        y.as_mut_slice().fill(T::ZERO);
        return;
    }
    let x_s = x.as_slice();
    T::with_scratch(b * r, |y_p| {
        // Phase 1: pivot-row dots, scattered into Y as they are produced.
        // y_p is fully written here (every (bi, k)), so the unspecified
        // scratch contents never escape.
        {
            let y_ptr = SendPtr::new(y.as_mut_slice().as_mut_ptr());
            let yp_ptr = SendPtr::new(y_p.as_mut_ptr());
            super::scope_chunks(r, 2 * b * r * n, |k0, k1| {
                for k in k0..k1 {
                    let wrow = layer.w_p.row(k);
                    let piv = layer.pivots[k];
                    for bi in 0..b {
                        let v = dot(wrow, &x_s[bi * n..(bi + 1) * n]);
                        // SAFETY: pivot indices are unique and each chunk
                        // owns a disjoint k-range, so every (bi, k) /
                        // (bi, piv) element is written by exactly one job.
                        unsafe {
                            yp_ptr.write(bi * r + k, v);
                            y_ptr.write(bi * m + piv, v);
                        }
                    }
                }
            });
        }

        // Phase 2: non-pivot rows combine the completed y_p.
        {
            let nnp = layer.non_pivots.len();
            let y_ptr = SendPtr::new(y.as_mut_slice().as_mut_ptr());
            let y_p: &[T] = y_p;
            super::scope_chunks(nnp, 2 * b * nnp * r, |k0, k1| {
                for k in k0..k1 {
                    let crow = layer.c.row(k);
                    let np = layer.non_pivots[k];
                    for bi in 0..b {
                        let v = dot(crow, &y_p[bi * r..(bi + 1) * r]);
                        // SAFETY: non-pivot indices are unique and disjoint
                        // from pivot indices; chunks own disjoint k-ranges.
                        unsafe { y_ptr.write(bi * m + np, v) };
                    }
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{self, Rng};
    use crate::pifa::{pivoting_factorization, PivotStrategy};

    fn layer_for(m: usize, n: usize, r: usize, seed: u64) -> (Mat<f64>, PifaLayer<f64>) {
        let mut rng = Rng::new(seed);
        let w: Mat<f64> = Mat::rand_low_rank(m, n, r, &mut rng);
        (w.clone(), pivoting_factorization(&w, r, PivotStrategy::QrColumnPivot).unwrap())
    }

    #[test]
    fn fused_matches_unfused_and_dense() {
        let mut rng = Rng::new(611);
        for &(m, n, r) in &[(8usize, 8usize, 1usize), (24, 16, 6), (16, 24, 8), (30, 30, 15)] {
            let (w, layer) = layer_for(m, n, r, 612 + m as u64);
            for b in 1..=6 {
                let x: Mat<f64> = Mat::randn(b, n, &mut rng);
                let fused = pifa_apply_rows_fused(&layer, &x);
                let unfused = layer.apply_rows_unfused(&x);
                assert!(
                    fused.rel_fro_err(&unfused) < 1e-11,
                    "({m},{n},{r}) b={b}: {}",
                    fused.rel_fro_err(&unfused)
                );
                let dense = linalg::matmul_nt(&x, &w);
                assert!(fused.rel_fro_err(&dense) < 1e-9, "({m},{n},{r}) b={b} vs dense");
            }
        }
    }

    #[test]
    fn into_overwrites_stale_output() {
        let (w, layer) = layer_for(24, 16, 6, 620);
        let mut rng = Rng::new(621);
        let x: Mat<f64> = Mat::randn(3, 16, &mut rng);
        // Garbage-prefilled reusable output must be fully overwritten
        // (pivot rows by phase 1, non-pivot rows by phase 2).
        let mut y: Mat<f64> = Mat::full(3, 24, 9.0);
        pifa_apply_rows_fused_into(&layer, &x, &mut y);
        assert!(y.rel_fro_err(&linalg::matmul_nt(&x, &w)) < 1e-9);
        // Reuse the same buffer for a second batch: thread-local scratch
        // and output are both recycled.
        let x2: Mat<f64> = Mat::randn(3, 16, &mut rng);
        pifa_apply_rows_fused_into(&layer, &x2, &mut y);
        assert!(y.rel_fro_err(&linalg::matmul_nt(&x2, &w)) < 1e-9);
    }

    #[test]
    fn full_rank_layer_has_no_phase_two() {
        // r = m: every output element comes from phase 1's scatter.
        let (w, layer) = layer_for(10, 12, 10, 613);
        let mut rng = Rng::new(614);
        let x: Mat<f64> = Mat::randn(2, 12, &mut rng);
        let y = pifa_apply_rows_fused(&layer, &x);
        assert!(y.rel_fro_err(&linalg::matmul_nt(&x, &w)) < 1e-10);
    }

    #[test]
    fn large_layer_trips_the_pool_and_still_matches() {
        // Synthetic layer (random permutation + factors): phase 1 costs
        // 2 * 4 * 512 * 1024 = 2^22 flops, so both phases chunk across
        // the pool. The kernel only reads the storage layout, so a valid
        // factorization is not needed to differentially test it.
        let mut rng = Rng::new(615);
        let (m, n, r) = (1024usize, 1024usize, 512usize);
        let mut idx: Vec<usize> = (0..m).collect();
        rng.shuffle(&mut idx);
        let pivots = idx[..r].to_vec();
        let mut non_pivots = idx[r..].to_vec();
        non_pivots.sort_unstable();
        let layer: PifaLayer<f64> = PifaLayer::new(
            m,
            n,
            pivots,
            non_pivots,
            Mat::randn(r, n, &mut rng),
            Mat::randn(m - r, r, &mut rng),
        );
        let x: Mat<f64> = Mat::randn(4, n, &mut rng);
        let fused = pifa_apply_rows_fused(&layer, &x);
        let unfused = layer.apply_rows_unfused(&x);
        assert!(fused.rel_fro_err(&unfused) < 1e-10, "{}", fused.rel_fro_err(&unfused));
    }
}
