//! Runtime-dispatched SIMD tier for the f32 decode kernels (DESIGN.md §7).
//!
//! The wide kernels here are written as portable `[f32; 8]` lane blocks —
//! plain safe Rust that LLVM turns into packed vector code — and are
//! additionally instantiated under `#[target_feature(enable = "avx2",
//! enable = "fma")]` on x86_64, so release builds emit 256-bit FMA even
//! when the crate's baseline target is generic. Two independent switches
//! pick the path at runtime:
//!
//! * **Mode** (cached in an atomic): `PIFA_SIMD=0|off|scalar|false` forces
//!   the scalar tier; any other value, or unset, enables the wide tier.
//!   [`set_mode`] overrides the env knob for bench A/B rows and soak
//!   rotation.
//! * **Instruction set**: on x86_64 the AVX2+FMA build of each kernel is
//!   used iff `is_x86_feature_detected!` confirms both features at
//!   runtime; otherwise (and on every other arch) the portable build
//!   runs, compiled for the baseline target.
//!
//! The wide tier reduces through 8 partial chains + a pairwise tree, so
//! its reduction order differs from the 4-chain scalar kernels: the
//! differential suites pin wide against scalar with a bounded tolerance,
//! not bitwise (`rust/tests/kernel_differential.rs`). The fused PIFA
//! apply needs no code here — both its phases funnel through
//! [`crate::runtime::kernels::gemv::dot`], which consults this module via
//! the `Scalar::simd_dot` hook.

use super::DECODE_BATCH_MAX;
use std::sync::atomic::{AtomicU8, Ordering};

/// Lane width of the portable wide kernels (f32 lanes per block).
pub const LANES: usize = 8;

const MODE_UNSET: u8 = 0;
const MODE_ON: u8 = 1;
const MODE_OFF: u8 = 2;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

/// Whether the wide tier is active. The first call resolves the
/// `PIFA_SIMD` env knob and caches the answer; [`set_mode`] replaces it.
#[inline]
pub fn enabled() -> bool {
    match MODE.load(Ordering::Relaxed) {
        MODE_ON => true,
        MODE_OFF => false,
        _ => {
            let on = env_default();
            MODE.store(if on { MODE_ON } else { MODE_OFF }, Ordering::Relaxed);
            on
        }
    }
}

/// Override the SIMD/scalar choice at runtime (bench A/B rows, soak
/// rotation): `true` selects the wide tier, `false` the scalar tier.
pub fn set_mode(on: bool) {
    MODE.store(if on { MODE_ON } else { MODE_OFF }, Ordering::Relaxed);
}

fn env_default() -> bool {
    match std::env::var("PIFA_SIMD") {
        Ok(v) => {
            let v = v.trim().to_ascii_lowercase();
            !matches!(v.as_str(), "0" | "off" | "scalar" | "false")
        }
        Err(_) => true,
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2_fma() -> bool {
    static DETECT: AtomicU8 = AtomicU8::new(0);
    match DETECT.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let ok = is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma");
            DETECT.store(if ok { 1 } else { 2 }, Ordering::Relaxed);
            ok
        }
    }
}

/// Pairwise tree reduction of one wide accumulator block.
#[inline(always)]
fn reduce(acc: &[f32; LANES]) -> f32 {
    let s01 = acc[0] + acc[1];
    let s23 = acc[2] + acc[3];
    let s45 = acc[4] + acc[5];
    let s67 = acc[6] + acc[7];
    (s01 + s23) + (s45 + s67)
}

// --- Portable wide cores -------------------------------------------------
//
// Each core is `#[inline(always)]` so the `#[target_feature]` wrappers in
// `x86` re-specialize the same source under AVX2+FMA codegen.

#[inline(always)]
fn dot_wide(a: &[f32], b: &[f32]) -> f32 {
    let len = a.len().min(b.len());
    let mut acc = [0f32; LANES];
    let mut i = 0;
    while i + LANES <= len {
        let ab = &a[i..i + LANES];
        let bb = &b[i..i + LANES];
        for l in 0..LANES {
            acc[l] = ab[l].mul_add(bb[l], acc[l]);
        }
        i += LANES;
    }
    let mut tail = 0f32;
    while i < len {
        tail = a[i].mul_add(b[i], tail);
        i += 1;
    }
    reduce(&acc) + tail
}

#[inline(always)]
fn batch_dot_wide(a: &[f32], bm: usize, k: usize, brow: &[f32], out: &mut [f32]) {
    debug_assert!(bm <= DECODE_BATCH_MAX && out.len() >= bm);
    debug_assert!(a.len() >= bm * k && brow.len() >= k);
    let mut acc = [[0f32; LANES]; DECODE_BATCH_MAX];
    let mut tails = [0f32; DECODE_BATCH_MAX];
    let mut i = 0;
    while i + LANES <= k {
        let bb = &brow[i..i + LANES];
        for (bi, accb) in acc.iter_mut().enumerate().take(bm) {
            let ab = &a[bi * k + i..bi * k + i + LANES];
            for l in 0..LANES {
                accb[l] = ab[l].mul_add(bb[l], accb[l]);
            }
        }
        i += LANES;
    }
    while i < k {
        let bv = brow[i];
        for (bi, t) in tails.iter_mut().enumerate().take(bm) {
            *t = a[bi * k + i].mul_add(bv, *t);
        }
        i += 1;
    }
    for bi in 0..bm {
        out[bi] = reduce(&acc[bi]) + tails[bi];
    }
}

#[inline(always)]
fn s24_row_dot_wide(vals: &[f32], metas: &[u8], x: &[f32]) -> f32 {
    let groups = metas.len();
    debug_assert!(vals.len() >= groups * 2 && x.len() >= groups * 4);
    let mut acc = [0f32; LANES];
    let mut g = 0;
    // Four groups (8 kept values) per block: one accumulator chain per
    // kept value, so the gather latency of the metadata-indexed loads
    // overlaps across chains.
    while g + 4 <= groups {
        for u in 0..4 {
            let gg = g + u;
            let byte = metas[gg];
            let base = gg * 4;
            acc[2 * u] = vals[gg * 2].mul_add(x[base + (byte & 0b11) as usize], acc[2 * u]);
            acc[2 * u + 1] =
                vals[gg * 2 + 1].mul_add(x[base + ((byte >> 2) & 0b11) as usize], acc[2 * u + 1]);
        }
        g += 4;
    }
    while g < groups {
        let byte = metas[g];
        let base = g * 4;
        acc[0] = vals[g * 2].mul_add(x[base + (byte & 0b11) as usize], acc[0]);
        acc[1] = vals[g * 2 + 1].mul_add(x[base + ((byte >> 2) & 0b11) as usize], acc[1]);
        g += 1;
    }
    reduce(&acc)
}

#[inline(always)]
fn q8_row_dot_wide(vals: &[i8], metas: &[u8], x: &[f32]) -> f32 {
    let groups = metas.len();
    debug_assert!(vals.len() >= groups * 2 && x.len() >= groups * 4);
    let mut acc = [0f32; LANES];
    let mut g = 0;
    while g + 4 <= groups {
        for u in 0..4 {
            let gg = g + u;
            let byte = metas[gg];
            let base = gg * 4;
            acc[2 * u] =
                (vals[gg * 2] as f32).mul_add(x[base + (byte & 0b11) as usize], acc[2 * u]);
            acc[2 * u + 1] = (vals[gg * 2 + 1] as f32)
                .mul_add(x[base + ((byte >> 2) & 0b11) as usize], acc[2 * u + 1]);
        }
        g += 4;
    }
    while g < groups {
        let byte = metas[g];
        let base = g * 4;
        acc[0] = (vals[g * 2] as f32).mul_add(x[base + (byte & 0b11) as usize], acc[0]);
        acc[1] =
            (vals[g * 2 + 1] as f32).mul_add(x[base + ((byte >> 2) & 0b11) as usize], acc[1]);
        g += 1;
    }
    reduce(&acc)
}

// --- AVX2 + FMA instantiations -------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::*;

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        dot_wide(a, b)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn batch_dot(a: &[f32], bm: usize, k: usize, brow: &[f32], out: &mut [f32]) {
        batch_dot_wide(a, bm, k, brow, out)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn s24_row_dot(vals: &[f32], metas: &[u8], x: &[f32]) -> f32 {
        s24_row_dot_wide(vals, metas, x)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn q8_row_dot(vals: &[i8], metas: &[u8], x: &[f32]) -> f32 {
        q8_row_dot_wide(vals, metas, x)
    }
}

// --- Public entry points --------------------------------------------------

/// Wide dot product. Unconditional (ignores the mode) — generic callers
/// gate through [`dot_checked`] / the `Scalar::simd_dot` hook; the
/// differential tests call this directly to pin it against the scalar
/// kernel regardless of the ambient mode.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_fma() {
            // SAFETY: AVX2 + FMA presence verified by runtime detection.
            return unsafe { x86::dot(a, b) };
        }
    }
    dot_wide(a, b)
}

/// [`dot`] gated on the runtime mode: `None` means "use the scalar tier"
/// (this is what the f32 `Scalar::simd_dot` hook returns when the mode is
/// off, so `gemv::dot` falls through to its own loop).
#[inline]
pub fn dot_checked(a: &[f32], b: &[f32]) -> Option<f32> {
    if enabled() {
        Some(dot(a, b))
    } else {
        None
    }
}

/// Batched dot of up to [`DECODE_BATCH_MAX`] rows of the row-major
/// `bm x k` slice `a` against one shared `brow`, writing
/// `out[bi] = <a[bi], brow>`. Unconditional — see [`batch_dot_checked`].
#[inline]
pub fn batch_dot(a: &[f32], bm: usize, k: usize, brow: &[f32], out: &mut [f32]) {
    assert!(bm <= DECODE_BATCH_MAX, "simd::batch_dot: batch {bm} exceeds {DECODE_BATCH_MAX}");
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_fma() {
            // SAFETY: AVX2 + FMA presence verified by runtime detection.
            unsafe { x86::batch_dot(a, bm, k, brow, out) };
            return;
        }
    }
    batch_dot_wide(a, bm, k, brow, out)
}

/// [`batch_dot`] gated on the runtime mode; returns `true` when the wide
/// tier handled the call (the `Scalar::simd_batch_dot` hook for f32).
#[inline]
pub fn batch_dot_checked(a: &[f32], bm: usize, k: usize, brow: &[f32], out: &mut [f32]) -> bool {
    if !enabled() {
        return false;
    }
    batch_dot(a, bm, k, brow, out);
    true
}

/// Packed 2:4 row dot (8 accumulator chains over 4-group blocks).
/// Unconditional — `Sparse24Mat::row_dot_packed` gates on [`enabled`].
#[inline]
pub fn s24_row_dot(vals: &[f32], metas: &[u8], x: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_fma() {
            // SAFETY: AVX2 + FMA presence verified by runtime detection.
            return unsafe { x86::s24_row_dot(vals, metas, x) };
        }
    }
    s24_row_dot_wide(vals, metas, x)
}

/// Int8 packed 2:4 row dot: accumulates `Σ q·x` in f32 — the caller
/// applies the per-row scale once. Unconditional —
/// `QuantSparse24Mat::row_dot_packed` gates on [`enabled`].
#[inline]
pub fn q8_row_dot(vals: &[i8], metas: &[u8], x: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_fma() {
            // SAFETY: AVX2 + FMA presence verified by runtime detection.
            return unsafe { x86::q8_row_dot(vals, metas, x) };
        }
    }
    q8_row_dot_wide(vals, metas, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn wide_dot_matches_naive_all_tails() {
        let mut rng = Rng::new(701);
        for len in [0usize, 1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 64, 100, 257] {
            let a = randv(len, &mut rng);
            let b = randv(len, &mut rng);
            let want: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            let got = dot(&a, &b) as f64;
            assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()), "len={len}: {got} vs {want}");
        }
    }

    #[test]
    fn batch_dot_matches_per_row_dot() {
        let mut rng = Rng::new(702);
        for bm in 1..=DECODE_BATCH_MAX {
            for k in [1usize, 3, 7, 8, 9, 31, 64, 129] {
                let a = randv(bm * k, &mut rng);
                let brow = randv(k, &mut rng);
                let mut out = [0f32; DECODE_BATCH_MAX];
                batch_dot(&a, bm, k, &brow, &mut out);
                for bi in 0..bm {
                    let want = dot(&a[bi * k..(bi + 1) * k], &brow);
                    assert!(
                        (out[bi] - want).abs() <= 1e-4 * (1.0 + want.abs()),
                        "bm={bm} k={k} bi={bi}"
                    );
                }
            }
        }
    }

    #[test]
    fn mode_override_roundtrip() {
        let before = enabled();
        set_mode(false);
        assert!(!enabled());
        set_mode(true);
        assert!(enabled());
        set_mode(before);
    }

    #[test]
    fn nan_and_inf_propagate() {
        let a = vec![1.0f32, f32::NAN, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let b = vec![1.0f32; 9];
        assert!(dot(&a, &b).is_nan());
        let c = vec![1.0f32, f32::INFINITY, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        assert!(dot(&c, &b).is_infinite());
    }
}
