//! `runtime::kernels` — structure-aware decode fast paths (DESIGN.md §7).
//!
//! Every `LinearRepr` forward funnels through `linalg::gemm` for
//! calibration-time shapes, but the serving scheduler spends its decode
//! iterations at batch ≤ [`DECODE_BATCH_MAX`], where the blocked GEMM is
//! the wrong shape (it parallelizes over batch rows) and per-call thread
//! spawns dominate. This subsystem provides:
//!
//! * [`pool`] — a persistent scoped thread pool shared by *all* kernels
//!   (the old per-`matmul` `thread::scope` spawns are gone).
//! * [`gemv`] — batch-≤-4 `Y = X W^T` kernels that stream the long axis
//!   and keep one accumulator per lane ([`gemv::skinny_nt`]).
//! * [`fused`] — the one-pass PIFA apply
//!   ([`fused::pifa_apply_rows_fused`]): pivot dots scatter straight
//!   into `Y`, non-pivot rows combine the `y_p` scratch, no intermediate
//!   `Mat` allocations.
//! * [`gather`] — paged-KV access kernels: the `(L, B, S, d)` merged
//!   gather for the PJRT decode artifact and the per-lane raw-slab views
//!   a parallel native decode iteration writes through (DESIGN.md §8).
//! * [`simd`] — the runtime-dispatched f32 wide-lane tier underneath the
//!   kernels above (portable `[f32; 8]` blocks, AVX2+FMA instantiation
//!   on detected x86_64, `PIFA_SIMD` / [`simd::set_mode`] override).
//! * the packed 2:4 decode mat-vec lives with its storage in
//!   [`crate::sparse24::Sparse24Mat::matvec`] (it needs the private
//!   values/meta layout); dispatch is documented here because it follows
//!   the same rules.
//!
//! ## Dispatch rules
//!
//! | call                          | condition                  | path                  |
//! |-------------------------------|----------------------------|-----------------------|
//! | `linalg::matmul_nt(x, w)`     | `x.rows() <= 4`            | `gemv::skinny_nt`     |
//! | `linalg::matmul*`             | `2mnk >= 2^22` flops       | pool-banded GEMM      |
//! | `linalg::matmul*`             | below threshold            | single-thread blocked |
//! | `PifaLayer::apply_rows`       | `x.rows() <= 4`            | fused one-pass apply  |
//! | `Sparse24Mat::apply_rows`     | `x.rows() <= 4`            | packed decode mat-vec |
//! | `QuantSparse24Mat::apply_rows`| `x.rows() <= 4`            | int8 decode mat-vec   |
//! | f32 inner dots (all above)    | `PIFA_SIMD` on (default)   | [`simd`] wide tier    |
//! | f32 inner dots (all above)    | `PIFA_SIMD=off`            | 4-chain scalar loop   |
//!
//! The wide tier is selected per call through the `Scalar::simd_*` hooks
//! (f64 always takes the scalar loop); within the wide tier the AVX2+FMA
//! build runs iff runtime detection confirms the features, else the
//! portable build. Every fast path is differentially tested against the
//! generic path it replaces (`rust/tests/kernel_differential.rs` + the
//! module tests here); refactors cannot silently diverge.

pub mod fused;
pub mod gather;
pub mod gemv;
pub mod pool;
pub mod simd;

/// Largest micro-batch the decode kernels specialize for. The serving
/// scheduler coalesces at most a handful of same-position lanes per
/// iteration; beyond this the blocked GEMM wins again.
pub const DECODE_BATCH_MAX: usize = 4;

/// Minimum FLOPs before splitting a kernel across the pool (shared with
/// `linalg::gemm`; below this the queue push costs more than it buys).
pub const PAR_FLOP_THRESHOLD: usize = 1 << 22;

/// Number of pool chunks for `units` independent work items costing
/// `flops` in total: 1 below the threshold, else capped by both the
/// pool's parallelism and the unit count.
pub fn par_chunks(units: usize, flops: usize) -> usize {
    if flops < PAR_FLOP_THRESHOLD || units <= 1 {
        1
    } else {
        pool::max_parallelism().min(units).max(1)
    }
}

/// Run `f(lo, hi)` over contiguous chunks of `[0, len)`, sized for the
/// pool when `flops` crosses [`PAR_FLOP_THRESHOLD`] (one inline chunk
/// otherwise). Every kernel's banding goes through here so the
/// disjointness argument for raw-pointer output writes — chunks never
/// overlap and cover the range exactly once — lives in one audited
/// place.
pub fn scope_chunks(len: usize, flops: usize, f: impl Fn(usize, usize) + Sync) {
    if len == 0 {
        return;
    }
    let chunk = len.div_ceil(par_chunks(len, flops));
    pool::scope_run(len.div_ceil(chunk), |ci| {
        let lo = ci * chunk;
        f(lo, (lo + chunk).min(len));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_respects_threshold_and_units() {
        assert_eq!(par_chunks(100, PAR_FLOP_THRESHOLD - 1), 1);
        assert_eq!(par_chunks(1, usize::MAX), 1);
        assert_eq!(par_chunks(0, usize::MAX), 1);
        let c = par_chunks(1000, PAR_FLOP_THRESHOLD);
        assert!(c >= 1 && c <= 1000);
        assert!(c <= pool::max_parallelism());
    }

    #[test]
    fn scope_chunks_covers_range_exactly_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for &(len, flops) in
            &[(0usize, usize::MAX), (1, 0), (7, 0), (100, PAR_FLOP_THRESHOLD), (1000, usize::MAX)]
        {
            let hits: Vec<AtomicUsize> = (0..len).map(|_| AtomicUsize::new(0)).collect();
            scope_chunks(len, flops, |lo, hi| {
                assert!(lo < hi && hi <= len, "bad chunk [{lo}, {hi}) of {len}");
                for h in &hits[lo..hi] {
                    h.fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "({len}, {flops}): range not covered exactly once"
            );
        }
    }
}
