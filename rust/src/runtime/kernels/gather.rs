//! Paged-KV gather/scatter kernels (DESIGN.md §8).
//!
//! The block pool (`runtime::kvpool`) stores K/V rows scattered across
//! fixed-size blocks; two consumers need flat access:
//!
//! * [`gather_merged`] — materialize every lane's rows into the
//!   contiguous `(L, B, S, d)` layout the static-batch PJRT decode
//!   artifact consumes (positions beyond a lane's length are
//!   zero-filled). Banded across the kernel pool via
//!   [`super::scope_chunks`].
//! * [`LaneView`] — a per-lane [`KvStore`] over raw slab pointers, so a
//!   shared decode iteration can advance independent lanes in parallel
//!   (`NativeBackend::step`). Reads go through shared slices; the single
//!   row written per layer lives in the lane's privately owned tail
//!   block.
//!
//! ## Disjointness argument (why the raw-pointer writes are sound)
//!
//! Before the parallel section, every stepped lane runs
//! `BlockPool::append` serially; `append` guarantees the block holding
//! the pending position is referenced by *exactly one* table (fresh
//! allocation, or a copy-on-write fork of a shared block). Therefore,
//! for distinct lanes `a != b`:
//! `write-region(a) ∩ (read-region(b) ∪ write-region(b)) = ∅` — lane
//! `a`'s writes land in a block that appears in no other lane's table.
//! Within a lane, reads and writes happen on one thread. The pool's
//! bookkeeping (free lists, sharing index) is never touched while views
//! are alive.

use super::pool::SendPtr;
use super::scope_chunks;
use crate::model::transformer::{KvStore, KvStoreFull};
use crate::runtime::kvpool::{BlockPool, KvPoolConfig, SeqKv};

/// Gather every lane's resident rows into contiguous `(L, B, S, d)`
/// K and V buffers (`S = max_seq`); positions at or beyond a lane's
/// length — and lanes without a table — are zero-filled.
pub fn gather_merged(
    pool: &BlockPool,
    tables: &[Option<&SeqKv>],
    max_seq: usize,
    out_k: &mut [f32],
    out_v: &mut [f32],
) {
    let cfg = pool.config();
    let (layers, dim) = (cfg.layers, cfg.dim);
    let lanes = tables.len();
    let stride = max_seq * dim;
    assert_eq!(out_k.len(), layers * lanes * stride, "gather_merged: bad K buffer");
    assert_eq!(out_v.len(), layers * lanes * stride, "gather_merged: bad V buffer");
    if lanes == 0 {
        return;
    }
    let pk = SendPtr::new(out_k.as_mut_ptr());
    let pv = SendPtr::new(out_v.as_mut_ptr());
    let units = layers * lanes;
    // Treat copied elements as the work estimate for banding.
    let work = 2 * units * stride;
    scope_chunks(units, work, |lo, hi| {
        for u in lo..hi {
            let (layer, lane) = (u / lanes, u % lanes);
            let dst = u * stride;
            // SAFETY: unit `u` owns exactly `[dst, dst + stride)`;
            // `scope_chunks` hands out disjoint unit ranges covering
            // `0..units` once, and the buffers outlive the scope.
            let dk = unsafe { pk.slice_mut(dst, stride) };
            let dv = unsafe { pv.slice_mut(dst, stride) };
            match tables[lane] {
                Some(seq) => {
                    let n = seq.len().min(max_seq);
                    for pos in 0..n {
                        dk[pos * dim..(pos + 1) * dim]
                            .copy_from_slice(pool.k_row(seq, layer, pos));
                        dv[pos * dim..(pos + 1) * dim]
                            .copy_from_slice(pool.v_row(seq, layer, pos));
                    }
                    dk[n * dim..].fill(0.0);
                    dv[n * dim..].fill(0.0);
                }
                None => {
                    dk.fill(0.0);
                    dv.fill(0.0);
                }
            }
        }
    });
}

/// Per-lane [`KvStore`] over the pool's raw slabs for one *pre-reserved*
/// decode step (see the module-level disjointness argument). Build with
/// [`lane_views`] after `BlockPool::append` reserved each lane's pending
/// position.
pub struct LaneView {
    k: SendPtr<f32>,
    v: SendPtr<f32>,
    blocks: Vec<usize>,
    /// Logical length *before* the pending pre-reserved position, i.e.
    /// the position the decode step writes.
    len: usize,
    layers: usize,
    block_tokens: usize,
    dim: usize,
    pending: bool,
}

impl LaneView {
    fn from_parts(k: SendPtr<f32>, v: SendPtr<f32>, cfg: &KvPoolConfig, seq: &SeqKv) -> Self {
        assert!(!seq.is_empty(), "LaneView needs a pre-reserved pending position");
        Self {
            k,
            v,
            blocks: seq.blocks().to_vec(),
            len: seq.len() - 1,
            layers: cfg.layers,
            block_tokens: cfg.block_tokens,
            dim: cfg.dim,
            pending: true,
        }
    }

    #[inline]
    fn row_offset(&self, layer: usize, pos: usize) -> usize {
        let block = self.blocks[pos / self.block_tokens];
        let row = pos % self.block_tokens;
        ((block * self.layers + layer) * self.block_tokens + row) * self.dim
    }
}

/// Snapshot one [`LaneView`] per lane whose next position was already
/// reserved via `BlockPool::append` (so each `seq.len()` is the
/// *post*-append length). All views derive their raw slab pointers from
/// this call's single exclusive pool borrow — constructing them from
/// separate `&mut` borrows would invalidate the earlier views' pointers
/// under Stacked Borrows.
pub fn lane_views(pool: &mut BlockPool, seqs: &[&SeqKv]) -> Vec<LaneView> {
    let cfg = pool.config().clone();
    let (k, v) = pool.slab_ptrs();
    let (k, v) = (SendPtr::new(k), SendPtr::new(v));
    seqs.iter().map(|seq| LaneView::from_parts(k, v, &cfg, seq)).collect()
}

impl KvStore for LaneView {
    fn len(&self) -> usize {
        self.len
    }

    fn reserve(&mut self, _token: usize) -> Result<(), KvStoreFull> {
        if !self.pending {
            return Err(KvStoreFull {
                pos: self.len + 1,
                detail: "LaneView holds a single pre-reserved position".into(),
            });
        }
        self.pending = false;
        Ok(())
    }

    fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        debug_assert!(pos <= self.len);
        let at = self.row_offset(layer, pos);
        // SAFETY: in-bounds row of the pool slab; concurrent writers only
        // touch blocks absent from this lane's table (module docs).
        unsafe { &*self.k.slice_mut(at, self.dim) }
    }

    fn v_row(&self, layer: usize, pos: usize) -> &[f32] {
        debug_assert!(pos <= self.len);
        let at = self.row_offset(layer, pos);
        // SAFETY: as `k_row`.
        unsafe { &*self.v.slice_mut(at, self.dim) }
    }

    fn write_row(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(pos, self.len, "LaneView writes only the pending position");
        let at = self.row_offset(layer, pos);
        // SAFETY: the pending row lives in this lane's privately owned
        // tail block (module docs); no other thread touches it.
        unsafe {
            self.k.slice_mut(at, k.len()).copy_from_slice(k);
            self.v.slice_mut(at, v.len()).copy_from_slice(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kernels::pool;
    use crate::runtime::kvpool::KvPoolConfig;
    use std::sync::Mutex;

    fn small_pool() -> BlockPool {
        BlockPool::new(KvPoolConfig { layers: 2, dim: 3, block_tokens: 2, num_blocks: 8 })
    }

    /// Append `n` tokens writing k = lane*100 + layer*10 + pos (v = -k).
    fn fill_lane(pool: &mut BlockPool, lane: usize, n: usize) -> SeqKv {
        let (mut seq, _) = pool.begin(&[]);
        for i in 0..n {
            pool.append(&mut seq, 1000 * lane + i).unwrap();
            for layer in 0..2 {
                let val = (lane * 100 + layer * 10 + i) as f32;
                pool.k_row_mut(&seq, layer, i).fill(val);
                pool.v_row_mut(&seq, layer, i).fill(-val);
            }
        }
        seq
    }

    #[test]
    fn gather_matches_reference_and_zero_fills() {
        let mut p = small_pool();
        let s0 = fill_lane(&mut p, 0, 3);
        let s2 = fill_lane(&mut p, 2, 5);
        let (layers, dim, max_seq, lanes) = (2usize, 3usize, 6usize, 3usize);
        let stride = max_seq * dim;
        let mut out_k = vec![9.9f32; layers * lanes * stride];
        let mut out_v = vec![9.9f32; layers * lanes * stride];
        let tables = [Some(&s0), None, Some(&s2)];
        gather_merged(&p, &tables, max_seq, &mut out_k, &mut out_v);
        // Reference layout: ((layer * lanes + lane) * max_seq + pos) * dim.
        for layer in 0..layers {
            for (lane, len) in [(0usize, 3usize), (1, 0), (2, 5)] {
                for pos in 0..max_seq {
                    let at = ((layer * lanes + lane) * max_seq + pos) * dim;
                    let want = if pos < len {
                        (lane * 100 + layer * 10 + pos) as f32
                    } else {
                        0.0
                    };
                    assert_eq!(out_k[at], want, "k (l{layer}, lane{lane}, p{pos})");
                    assert_eq!(out_v[at], -want, "v (l{layer}, lane{lane}, p{pos})");
                }
            }
        }
        p.release(s0);
        p.release(s2);
    }

    #[test]
    fn lane_views_step_independent_lanes_in_parallel() {
        let mut p = small_pool();
        let mut s0 = fill_lane(&mut p, 0, 2);
        let mut s1 = fill_lane(&mut p, 1, 3);
        // Serial phase: reserve the pending position on both lanes.
        p.append(&mut s0, 7).unwrap();
        p.append(&mut s1, 8).unwrap();
        let views: Vec<Mutex<Option<LaneView>>> = lane_views(&mut p, &[&s0, &s1])
            .into_iter()
            .map(|v| Mutex::new(Some(v)))
            .collect();
        pool::scope_run(2, |i| {
            let mut view = views[i].lock().unwrap().take().unwrap();
            let pos = view.len();
            view.reserve(0).unwrap();
            // Reads see the serially written history...
            let base = (i * 100) as f32;
            assert_eq!(view.k_row(0, 0)[0], base);
            // ...and the write lands in the lane's own pending row.
            let val = [(500 + i) as f32; 3];
            for layer in 0..2 {
                view.write_row(layer, pos, &val, &val);
            }
        });
        assert_eq!(p.k_row(&s0, 0, 2)[0], 500.0);
        assert_eq!(p.k_row(&s1, 0, 3)[0], 501.0);
        // Pre-existing rows are untouched.
        assert_eq!(p.k_row(&s0, 1, 1)[0], 11.0);
        assert_eq!(p.v_row(&s1, 0, 2)[0], -102.0);
        p.release(s0);
        p.release(s1);
    }

    #[test]
    fn lane_view_rejects_a_second_reserve() {
        let mut p = small_pool();
        let mut s = fill_lane(&mut p, 0, 1);
        p.append(&mut s, 3).unwrap();
        let mut view = lane_views(&mut p, &[&s]).pop().unwrap();
        assert_eq!(view.len(), 1);
        view.reserve(3).unwrap();
        assert!(view.reserve(4).is_err());
        p.release(s);
    }
}
