//! GEMV / skinny-matmul decode kernels.
//!
//! The decode hot path computes `Y = X W^T` with `X (b x n)` for
//! `b <= DECODE_BATCH_MAX` (one token per active lane). The blocked GEMM
//! in `linalg::gemm` is shaped for calibration-time matrices: it bands
//! over the *batch* rows of `Y`, so at `b = 1` it cannot parallelize at
//! all and its K-blocking buys nothing. The kernels here flip the loop
//! structure: iterate over the rows of `W` (the long axis), keep up to
//! `DECODE_BATCH_MAX` accumulators live so each `W` row is streamed once
//! for the whole micro-batch, and split the `W` rows across the shared
//! pool above the FLOP threshold.

use super::pool::SendPtr;
use super::DECODE_BATCH_MAX;
use crate::linalg::{Mat, Scalar};

/// Dot product — the inner core of every decode kernel. Consults the
/// runtime-dispatched wide tier first (`Scalar::simd_dot`, f32 only —
/// see [`super::simd`]); otherwise runs the four-accumulator scalar
/// loop, whose independent chains let LLVM vectorize the `mul_add`
/// stream.
#[inline]
pub fn dot<T: Scalar>(a: &[T], b: &[T]) -> T {
    debug_assert_eq!(a.len(), b.len());
    if let Some(v) = T::simd_dot(a, b) {
        return v;
    }
    dot_scalar(a, b)
}

/// The scalar four-chain core [`dot`] falls back to. Public so the
/// kernel bench can time the scalar tier against [`super::simd::dot`]
/// regardless of what runtime detection picked for the wired path.
#[inline]
pub fn dot_scalar<T: Scalar>(a: &[T], b: &[T]) -> T {
    debug_assert_eq!(a.len(), b.len());
    let len = a.len();
    let mut acc0 = T::ZERO;
    let mut acc1 = T::ZERO;
    let mut acc2 = T::ZERO;
    let mut acc3 = T::ZERO;
    let mut i = 0;
    while i + 4 <= len {
        acc0 = a[i].mul_add_s(b[i], acc0);
        acc1 = a[i + 1].mul_add_s(b[i + 1], acc1);
        acc2 = a[i + 2].mul_add_s(b[i + 2], acc2);
        acc3 = a[i + 3].mul_add_s(b[i + 3], acc3);
        i += 4;
    }
    while i < len {
        acc0 = a[i].mul_add_s(b[i], acc0);
        i += 1;
    }
    (acc0 + acc1) + (acc2 + acc3)
}

/// Skinny `C = A B^T` with `A (b x k)`, `B (n x k)`, `b <= DECODE_BATCH_MAX`:
/// the batch-`b` GEMV. Each row of `B` is streamed once against all `b`
/// rows of `A`; rows of `B` are chunked across the pool. Allocates the
/// output — the steady-state decode loop should hold a reusable output
/// and call [`skinny_nt_into`] instead.
pub fn skinny_nt<T: Scalar>(a: &Mat<T>, b: &Mat<T>) -> Mat<T> {
    let mut c = Mat::zeros(a.rows(), b.rows());
    skinny_nt_into(a, b, &mut c);
    c
}

/// [`skinny_nt`] with a caller-owned output (`c` must be `b x n`). Makes
/// zero transient heap allocations: every output element is written, no
/// scratch is needed, and the pool path reuses its persistent workers
/// (below [`super::PAR_FLOP_THRESHOLD`] the chunk runs inline).
pub fn skinny_nt_into<T: Scalar>(a: &Mat<T>, b: &Mat<T>, c: &mut Mat<T>) {
    let (bm, k) = a.shape();
    let (n, k2) = b.shape();
    assert_eq!(k, k2, "skinny_nt: inner dim mismatch {bm}x{k} * {n}x{k2}");
    // Hard assert: the accumulator array below holds DECODE_BATCH_MAX
    // lanes, so a larger batch would silently drop rows in release.
    assert!(bm <= DECODE_BATCH_MAX, "skinny_nt: batch {bm} exceeds {DECODE_BATCH_MAX}");
    assert_eq!(c.shape(), (bm, n), "skinny_nt_into: output shape mismatch");
    if bm == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.as_mut_slice().fill(T::ZERO);
        return;
    }
    let a_s = a.as_slice();
    let b_s = b.as_slice();
    let c_ptr = SendPtr::new(c.as_mut_slice().as_mut_ptr());
    super::scope_chunks(n, 2 * bm * n * k, |j0, j1| {
        if bm == 1 {
            for j in j0..j1 {
                let brow = &b_s[j * k..(j + 1) * k];
                // SAFETY: each chunk owns columns [j0, j1) exclusively.
                unsafe { c_ptr.write(j, dot(a_s, brow)) };
            }
        } else {
            for j in j0..j1 {
                let brow = &b_s[j * k..(j + 1) * k];
                let mut acc = [T::ZERO; DECODE_BATCH_MAX];
                if !T::simd_batch_dot(a_s, bm, k, brow, &mut acc[..bm]) {
                    for (kk, &bv) in brow.iter().enumerate() {
                        for (bi, ac) in acc.iter_mut().enumerate().take(bm) {
                            *ac = a_s[bi * k + kk].mul_add_s(bv, *ac);
                        }
                    }
                }
                for (bi, ac) in acc.iter().enumerate().take(bm) {
                    // SAFETY: disjoint (bi, j) elements per chunk.
                    unsafe { c_ptr.write(bi * n + j, *ac) };
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn naive_nt(a: &Mat<f64>, b: &Mat<f64>) -> Mat<f64> {
        let (m, k) = a.shape();
        let n = b.rows();
        let mut c = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a[(i, kk)] * b[(j, kk)];
                }
                c[(i, j)] = acc;
            }
        }
        c
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(601);
        for len in [0usize, 1, 3, 4, 7, 64, 129] {
            let a: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let want: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-10, "len={len}");
        }
    }

    #[test]
    fn skinny_matches_naive_all_batches() {
        let mut rng = Rng::new(602);
        for bm in 1..=DECODE_BATCH_MAX {
            for &(n, k) in &[(1usize, 1usize), (5, 9), (33, 17), (128, 64)] {
                let a: Mat<f64> = Mat::randn(bm, k, &mut rng);
                let b: Mat<f64> = Mat::randn(n, k, &mut rng);
                let c = skinny_nt(&a, &b);
                assert!(c.rel_fro_err(&naive_nt(&a, &b)) < 1e-12, "b={bm} ({n},{k})");
            }
        }
    }

    #[test]
    fn skinny_parallel_chunks_match() {
        // Big enough to trip the pool threshold at batch 1.
        let mut rng = Rng::new(603);
        let a: Mat<f64> = Mat::randn(1, 2048, &mut rng);
        let b: Mat<f64> = Mat::randn(1200, 2048, &mut rng);
        let c = skinny_nt(&a, &b);
        assert!(c.rel_fro_err(&naive_nt(&a, &b)) < 1e-11);
    }

    #[test]
    fn into_overwrites_stale_output() {
        let mut rng = Rng::new(604);
        for bm in 1..=DECODE_BATCH_MAX {
            let a: Mat<f64> = Mat::randn(bm, 17, &mut rng);
            let b: Mat<f64> = Mat::randn(33, 17, &mut rng);
            // Garbage-prefilled reusable output must be fully overwritten.
            let mut c: Mat<f64> = Mat::full(bm, 33, 7.0);
            skinny_nt_into(&a, &b, &mut c);
            assert!(c.rel_fro_err(&naive_nt(&a, &b)) < 1e-12, "bm={bm}");
        }
    }

    #[test]
    #[should_panic(expected = "output shape mismatch")]
    fn into_rejects_wrong_output_shape() {
        let a: Mat<f64> = Mat::zeros(1, 3);
        let b: Mat<f64> = Mat::zeros(4, 3);
        let mut c: Mat<f64> = Mat::zeros(1, 5);
        skinny_nt_into(&a, &b, &mut c);
    }

    #[test]
    fn empty_dims_are_fine() {
        let a: Mat<f64> = Mat::zeros(1, 0);
        let b: Mat<f64> = Mat::zeros(7, 0);
        assert_eq!(skinny_nt(&a, &b), Mat::zeros(1, 7));
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn rejects_oversized_batch_even_in_release() {
        let a: Mat<f64> = Mat::zeros(DECODE_BATCH_MAX + 1, 3);
        let b: Mat<f64> = Mat::zeros(4, 3);
        let _ = skinny_nt(&a, &b);
    }
}
