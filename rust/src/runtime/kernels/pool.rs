//! Persistent scoped thread pool — the shared execution substrate of the
//! kernel layer (DESIGN.md §7).
//!
//! The old `linalg::gemm` spawned fresh `std::thread::scope` threads on
//! every call above the FLOP threshold; at decode time that meant a
//! spawn/join round-trip per token per layer. This pool spawns its
//! workers once (lazily, on first use) and keeps them parked on a
//! condvar, so a parallel kernel call costs a queue push + wakeup.
//!
//! Semantics of [`scope_run`]`(n, f)`:
//!
//! * `f(i)` is executed exactly once for every `i in 0..n`, possibly in
//!   parallel; the call returns only after all `n` jobs finished — so
//!   `f` may borrow from the caller's stack (a *scoped* pool).
//! * The submitting thread participates in the work, and nested calls
//!   from inside a job run inline on the current thread. Kernels can
//!   therefore call each other freely without deadlocking the pool or
//!   oversubscribing the machine.
//! * `PIFA_THREADS=k` caps total parallelism (submitter + workers) at
//!   `k`; `PIFA_THREADS=1` forces every kernel single-threaded (useful
//!   for bit-stable A/B timing). The default is
//!   `std::thread::available_parallelism()`. An invalid value (`0`, or
//!   anything that does not parse as a thread count) falls back to that
//!   default and prints one warning to stderr at first pool use — it is
//!   never silently swallowed, so soak-matrix repro runs cannot pin the
//!   wrong parallelism without a signal.
//!
//! A panic inside a job is caught on the worker, the remaining jobs
//! still run, and the panic is re-raised on the submitting thread once
//! the scope completes (so tests see the original assertion message).

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Copyable raw pointer that may cross the job boundary. Kernels use it
/// to hand each job a disjoint slice of one output buffer.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> Self {
        SendPtr(p)
    }

    /// Write `v` at element offset `idx`.
    ///
    /// # Safety
    /// `idx` must be in bounds of the allocation behind the pointer, the
    /// allocation must outlive the enclosing [`scope_run`], and no other
    /// thread may access the same element concurrently.
    #[inline(always)]
    pub unsafe fn write(self, idx: usize, v: T) {
        *self.0.add(idx) = v;
    }

    /// Mutable sub-slice `[off, off + len)` of the allocation.
    ///
    /// # Safety
    /// The range must be in bounds, the allocation must outlive the
    /// enclosing [`scope_run`], and no other thread may touch an
    /// overlapping range concurrently.
    #[inline(always)]
    pub unsafe fn slice_mut<'a>(self, off: usize, len: usize) -> &'a mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(off), len)
    }
}

/// Raw job closure with the borrow lifetime erased. Sound because
/// [`Pool::run`] joins every job before returning.
type TaskFn = *const (dyn Fn(usize) + Sync);

struct Task {
    f: TaskFn,
    n: usize,
    /// Next unclaimed job index.
    next: AtomicUsize,
    /// Jobs not yet completed.
    pending: AtomicUsize,
    /// First panic payload from any job, re-raised on the submitter.
    panic_payload: Mutex<Option<Box<dyn Any + Send>>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: `f` points at a `Sync` closure that the submitting thread keeps
// alive (and borrowed) until `pending` reaches zero.
unsafe impl Send for Task {}
unsafe impl Sync for Task {}

impl Task {
    /// Claim and run jobs until none are left; signal the submitter when
    /// the last job completes.
    fn run_to_completion(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                return;
            }
            let f = unsafe { &*self.f };
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(i))) {
                let mut slot = self.panic_payload.lock().unwrap();
                slot.get_or_insert(payload);
            }
            if self.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
                let mut d = self.done.lock().unwrap();
                *d = true;
                self.done_cv.notify_all();
            }
        }
    }
}

fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> TaskFn {
    // Lifetime-erasing cast; see the `Task` safety comment.
    unsafe { std::mem::transmute::<*const (dyn Fn(usize) + Sync + 'a), TaskFn>(f) }
}

struct Shared {
    queue: Mutex<VecDeque<Arc<Task>>>,
    work_cv: Condvar,
}

/// The persistent pool: spawned once, shared by every kernel call.
pub struct Pool {
    shared: Arc<Shared>,
    workers: usize,
}

thread_local! {
    /// True while the current thread is executing a pool job (worker or
    /// participating submitter); nested `scope_run` calls go inline.
    static IN_POOL_JOB: Cell<bool> = const { Cell::new(false) };
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        IN_POOL_JOB.with(|c| c.set(true));
        task.run_to_completion();
        IN_POOL_JOB.with(|c| c.set(false));
    }
}

impl Pool {
    /// Run `f(0..n)`, returning when all jobs completed. Runs inline when
    /// the pool has no workers, `n <= 1`, or the caller is itself a pool
    /// job (nested parallelism).
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        if self.workers == 0 || n == 1 || IN_POOL_JOB.with(|c| c.get()) {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let task = Arc::new(Task {
            f: erase(f),
            n,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n),
            panic_payload: Mutex::new(None),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        // One queue entry per worker that could usefully join; a popped
        // entry whose task is already fully claimed is a cheap no-op.
        let entries = self.workers.min(n);
        {
            let mut q = self.shared.queue.lock().unwrap();
            for _ in 0..entries {
                q.push_back(task.clone());
            }
        }
        if entries == 1 {
            self.shared.work_cv.notify_one();
        } else {
            self.shared.work_cv.notify_all();
        }
        // Participate, then wait out any straggler workers.
        IN_POOL_JOB.with(|c| c.set(true));
        task.run_to_completion();
        IN_POOL_JOB.with(|c| c.set(false));
        let mut d = task.done.lock().unwrap();
        while !*d {
            d = task.done_cv.wait(d).unwrap();
        }
        drop(d);
        if let Some(payload) = task.panic_payload.lock().unwrap().take() {
            resume_unwind(payload);
        }
    }
}

static POOL: OnceLock<Pool> = OnceLock::new();

/// Resolve the `PIFA_THREADS` override against the machine default:
/// returns the total parallelism plus an optional warning line for
/// invalid input (`0` or unparseable → fall back to `default`, warn).
/// Pure so the validation is unit-testable without re-initializing the
/// process-wide pool.
fn parse_threads(raw: Option<&str>, default: usize) -> (usize, Option<String>) {
    match raw {
        None => (default, None),
        Some(s) => match s.trim().parse::<usize>() {
            Ok(0) => (
                default,
                Some(format!(
                    "pifa: warning: PIFA_THREADS=0 is invalid (need >= 1); \
                     using default ({default})"
                )),
            ),
            Ok(k) => (k, None),
            Err(_) => (
                default,
                Some(format!(
                    "pifa: warning: PIFA_THREADS={s:?} is not a thread count; \
                     using default ({default})"
                )),
            ),
        },
    }
}

/// The process-wide pool (spawned on first use).
pub fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let default = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let raw = std::env::var("PIFA_THREADS").ok();
        let (total, warning) = parse_threads(raw.as_deref(), default);
        if let Some(w) = warning {
            // OnceLock init runs exactly once per process: one warning.
            eprintln!("{w}");
        }
        // The submitter participates, so spawn one fewer worker.
        let workers = total.saturating_sub(1);
        let shared =
            Arc::new(Shared { queue: Mutex::new(VecDeque::new()), work_cv: Condvar::new() });
        for i in 0..workers {
            let s = shared.clone();
            std::thread::Builder::new()
                .name(format!("pifa-kernel-{i}"))
                .spawn(move || worker_loop(s))
                .expect("kernels::pool: failed to spawn worker");
        }
        Pool { shared, workers }
    })
}

/// Run `f(i)` for every `i in 0..n` on the shared pool (see module docs).
pub fn scope_run(n: usize, f: impl Fn(usize) + Sync) {
    pool().run(n, &f);
}

/// Maximum useful parallelism: the participating submitter + workers.
pub fn max_parallelism() -> usize {
    pool().workers + 1
}

/// Force the pool into existence (backends call this at construction so
/// the first decode token does not pay the spawn cost).
pub fn prewarm() {
    let _ = pool();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_index_exactly_once() {
        for n in [0usize, 1, 2, 7, 64, 257] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            scope_run(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "n={n}");
        }
    }

    #[test]
    fn jobs_can_borrow_and_write_disjoint_output() {
        let mut out = vec![0usize; 100];
        let ptr = SendPtr::new(out.as_mut_ptr());
        scope_run(100, |i| unsafe { ptr.write(i, i * i) });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i * i));
    }

    #[test]
    fn nested_calls_run_inline_without_deadlock() {
        let mut out = vec![0usize; 16 * 8];
        let ptr = SendPtr::new(out.as_mut_ptr());
        scope_run(16, |i| {
            // Inner scope must not wait on the (possibly busy) pool.
            scope_run(8, |j| unsafe { ptr.write(i * 8 + j, i + j) });
        });
        for i in 0..16 {
            for j in 0..8 {
                assert_eq!(out[i * 8 + j], i + j);
            }
        }
    }

    #[test]
    fn concurrent_submitters_do_not_interfere() {
        let sums: Vec<usize> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|t| {
                    s.spawn(move || {
                        let n = 50 + t;
                        let acc: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                        scope_run(n, |i| {
                            acc[i].store(i + 1, Ordering::Relaxed);
                        });
                        acc.iter().map(|a| a.load(Ordering::Relaxed)).sum::<usize>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (t, got) in sums.iter().enumerate() {
            let n = 50 + t;
            assert_eq!(*got, n * (n + 1) / 2);
        }
    }

    #[test]
    fn job_panic_propagates_to_submitter() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            scope_run(8, |i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err());
        // The pool stays usable afterwards.
        let hit = AtomicUsize::new(0);
        scope_run(4, |_| {
            hit.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hit.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn parallelism_reports_at_least_one() {
        assert!(max_parallelism() >= 1);
        prewarm();
    }

    #[test]
    fn parse_threads_validates_the_env_knob() {
        // Unset: machine default, no warning.
        assert_eq!(parse_threads(None, 8), (8, None));
        // Valid values pass through untouched (1 = single-threaded).
        assert_eq!(parse_threads(Some("1"), 8), (1, None));
        assert_eq!(parse_threads(Some(" 16 "), 8), (16, None));
        // 0 is invalid: documented fallback + a warning that names it.
        let (total, warn) = parse_threads(Some("0"), 8);
        assert_eq!(total, 8);
        let warn = warn.expect("PIFA_THREADS=0 must warn");
        assert!(warn.contains("PIFA_THREADS=0") && warn.contains("default (8)"), "{warn}");
        // Garbage is invalid: same fallback, warning quotes the input.
        for bad in ["", "banana", "-3", "2.5", "0x8"] {
            let (total, warn) = parse_threads(Some(bad), 4);
            assert_eq!(total, 4, "input {bad:?}");
            let warn = warn.unwrap_or_else(|| panic!("PIFA_THREADS={bad:?} must warn"));
            assert!(warn.contains("not a thread count"), "{warn}");
        }
    }
}
