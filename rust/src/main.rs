//! `pifa` — CLI for the PIFA/MPIFA reproduction.
//!
//! Subcommands (hand-rolled parsing; no clap in the offline crate set):
//!
//! ```text
//! pifa train    --model tiny-s [--out PATH]
//! pifa compress --model tiny-s --method mpifa --density 0.55 [--out PATH]
//!               [--recon none|fullbatch|online] [--lambda F]
//!               [--pivot none|qr|lu] [--pack none|s24]
//! pifa methods  — list registered compression methods
//! pifa eval     --ckpt PATH [--corpus wiki|c4]   (prints provenance)
//! pifa generate --ckpt PATH --prompt "the banlanba ..." [--max-new N]
//! pifa serve    --model tiny-s --flavour dense|pifa [--method NAME]
//!               [--requests N] [--no-kv] [--native]
//!               [--max-batch N] [--max-wait-ms MS] [--queue-cap N]
//!               [--prefill-chunk N]
//!               [--temperature F] [--top-k N] [--kv-lanes N]
//!               [--kv-evict fifo|lru|freq] [--kv-spill] [--kv-compress]
//!               [--kv-rank-frac F]
//!               [--speculate METHOD] [--draft-k N]
//!               [--replicas N] [--drain ID]
//!               (+ the compress stage overrides; falls back to the
//!               Rust-native backend when PJRT/artifacts are absent).
//!               --max-batch 0 (default) uses the backend's lane cap —
//!               for the paged-KV native backend that is the block-pool
//!               watermark cap, so more concurrent sessions fit than the
//!               fixed-lane baseline at equal memory; --kv-lanes sizes
//!               the pool to that many contiguous max_seq lanes' bytes.
//!               --prefill-chunk (default 512, 0 = monolithic) is the
//!               per-iteration token budget for chunked prefill: each
//!               scheduler iteration decodes the active lanes first,
//!               then advances at most one in-flight prefill by up to
//!               that many tokens, so one long prompt cannot stall every
//!               active lane's inter-token latency (DESIGN.md §6).
//!               Block utilization + prefix-hit-rate print at shutdown.
//!               KV lifecycle (DESIGN.md §10, native paged backend only):
//!               --kv-evict picks the idle-block eviction policy,
//!               --kv-spill lets the scheduler preempt low-priority
//!               sessions into a host spill arena under block pressure,
//!               and --kv-compress stores cold spilled KV as a PIFA
//!               factorization at rank fraction --kv-rank-frac.
//!               Self-speculative decoding (DESIGN.md §11, native KV
//!               backend only): --speculate compresses the base dense
//!               checkpoint with the named registry method into a draft
//!               model that proposes --draft-k greedy tokens per
//!               iteration; the dense target verifies all k+1 positions
//!               and the output stays bitwise-identical to plain greedy
//!               decode. Acceptance counters print at shutdown.
//!               Router tier (DESIGN.md §12, native backend only):
//!               --replicas N serves through N identical replicas behind
//!               the prefix-aware router — each request routes to the
//!               replica most likely to hold its prompt's prefix blocks,
//!               spilling to the least-loaded healthy replica under
//!               saturation; --drain ID stops new placements to one
//!               replica mid-run while its active sessions finish (the
//!               rolling-restart primitive). Per-replica placements and
//!               the fleet rollup (global prefix-hit rate included)
//!               print at shutdown.
//! pifa tables   <fig1|tab2|tab3|...|all>   (same generators as cargo bench)
//! pifa bench-kernels [--smoke] [--out PATH]
//!               — decode-path kernel microbench (dense vs low-rank vs
//!               PIFA vs 2:4 vs hybrid across an (m, n, batch) grid);
//!               writes BENCH_kernels.json. --smoke runs the CI grid and
//!               fails unless the PIFA-vs-lowrank ratio is positive.
//! pifa bench-serve [--smoke] [--out PATH] [--model NAME] [--reps K]
//!               — end-to-end serving bench: open-loop seeded scenarios
//!               (Poisson/bursty arrivals, shared prefixes, cancel
//!               storms, deadline mixes) x the method registry through
//!               the continuous-batching scheduler; writes
//!               BENCH_serve.json (schema pifa-bench-serve-v1). --smoke
//!               trims to the CI grid and self-validates the output.
//! pifa bench-diff <baseline.json> <candidate.json> [--tolerance-scale F]
//!               — noise-aware regression gate over two bench reports
//!               (serve or kernels schema); exits non-zero on a gated
//!               regression, a dropped metric, or lost cell coverage.
//! pifa bench-diff --check-schema <file.json>
//!               — structural validation of one bench report (the loud
//!               replacement for grepping the JSON).
//! pifa info     — artifact + platform diagnostics
//! ```
//!
//! Compression methods resolve through `pifa::compress::registry` — there
//! is no method enum here. Stage overrides mutate the preset's
//! `PipelineSpec` before it runs, and the final spec is embedded in saved
//! checkpoints as provenance.

use anyhow::{anyhow, bail, Context, Result};
use pifa::bench::experiments::{self, ensure_trained_model, test_ppl};
use pifa::compress::pipeline::{self, FactorizeStage, PackStage, PipelineSpec, ReconStage};
use pifa::compress::registry::{self, CompressionOutput};
use pifa::compress::ReconTarget;
use pifa::coordinator::{
    DecodeBackend, Event, GenRequest, GenerationMode, KvLifeConfig, NativeBackend, PjrtBackend,
    Router, RouterConfig, SamplingParams, SchedulerConfig, Server,
};
use pifa::data::vocab::Vocab;
use pifa::model::serialize::{load_checkpoint, load_checkpoint_full, save_checkpoint_with_spec};
use pifa::model::transformer::Transformer;
use pifa::pifa::PivotStrategy;
use pifa::runtime::{DraftEngine, Engine, Manifest, ModelRunner, SpecConfig};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "1".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn artifact_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when any pipeline stage override flag is present.
fn has_stage_overrides(flags: &HashMap<String, String>) -> bool {
    ["recon", "lambda", "pivot", "pack"].iter().any(|k| flags.contains_key(*k))
}

/// Apply `--recon/--lambda/--pivot/--pack` onto a preset's spec.
fn apply_stage_overrides(spec: &mut PipelineSpec, flags: &HashMap<String, String>) -> Result<()> {
    if let Some(recon) = flags.get("recon") {
        spec.recon = match recon.as_str() {
            "none" => ReconStage::None,
            "fullbatch" | "u" => ReconStage::FullBatch { max_samples: 16 },
            "online" | "m" => {
                ReconStage::Online { target: ReconTarget::Both, lambda: 0.25, alpha: 1e-3 }
            }
            other => bail!("unknown --recon '{other}' (none|fullbatch|online)"),
        };
    }
    if let Some(lam) = flags.get("lambda") {
        let lambda: f64 = lam.parse().context("--lambda must be a number")?;
        match &mut spec.recon {
            ReconStage::Online { lambda: l, .. } => *l = lambda,
            other => bail!("--lambda only applies to online reconstruction (recon is {other:?})"),
        }
    }
    if let Some(pivot) = flags.get("pivot") {
        spec.factorize = match pivot.as_str() {
            "none" => FactorizeStage::None,
            "qr" => FactorizeStage::Pivot(PivotStrategy::QrColumnPivot),
            "lu" => FactorizeStage::Pivot(PivotStrategy::Lu),
            other => bail!("unknown --pivot '{other}' (none|qr|lu)"),
        };
    }
    if let Some(pack) = flags.get("pack") {
        spec.pack = match pack.as_str() {
            "none" => PackStage::None,
            "s24" | "sparse24-residual" => PackStage::Sparse24Residual,
            other => bail!("unknown --pack '{other}' (none|s24)"),
        };
    }
    spec.validate()
}

/// Resolve a method + overrides into a compressed model with its spec.
fn compress_via_registry(
    model: &pifa::model::transformer::Transformer,
    data: &pifa::data::batch::TokenDataset,
    method: &str,
    density: f64,
    flags: &HashMap<String, String>,
) -> Result<CompressionOutput> {
    let compressor = registry::get(method)?;
    if has_stage_overrides(flags) {
        let mut spec = compressor.spec(density).ok_or_else(|| {
            anyhow!(
                "preset '{}' selects among pipelines at compress time and does not accept \
                 stage overrides",
                compressor.name()
            )
        })?;
        apply_stage_overrides(&mut spec, flags)?;
        let compressed = pipeline::run(&spec, model, data)?;
        Ok(CompressionOutput { model: compressed, spec })
    } else {
        compressor.compress(model, data, density)
    }
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("model").map(String::as_str).unwrap_or("tiny-s");
    let model = ensure_trained_model(name)?;
    if let Some(out) = flags.get("out") {
        pifa::model::serialize::save_checkpoint(&model, Path::new(out))?;
        println!("saved {out}");
    }
    let data = experiments::wiki_dataset();
    println!("{name}: test ppl {:.3}", test_ppl(&model, &data));
    Ok(())
}

fn cmd_compress(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("model").map(String::as_str).unwrap_or("tiny-s");
    let method = flags.get("method").map(String::as_str).unwrap_or("mpifa");
    let density: f64 = flags.get("density").map(String::as_str).unwrap_or("0.55").parse()?;
    let model = ensure_trained_model(name)?;
    let data = experiments::wiki_dataset();
    let base = test_ppl(&model, &data);
    let t0 = std::time::Instant::now();
    let output = compress_via_registry(&model, &data, method, density, flags)?;
    let secs = t0.elapsed().as_secs_f64();
    let ppl = test_ppl(&output.model, &data);
    println!("pipeline: {}", output.spec.describe());
    println!(
        "{name} {} @ density {density}: ppl {base:.3} -> {ppl:.3} (achieved density {:.3}, {secs:.1}s)",
        registry::get(method)?.label(),
        output.model.density()
    );
    if let Some(out) = flags.get("out") {
        save_checkpoint_with_spec(&output.model, Path::new(out), Some(&output.spec.to_text()))?;
        println!("saved {out} (with pipeline provenance)");
    }
    Ok(())
}

fn cmd_methods() -> Result<()> {
    println!("registered compression methods:");
    for c in registry::all() {
        let aliases = if c.aliases().is_empty() {
            String::new()
        } else {
            format!(" (aliases: {})", c.aliases().join(", "))
        };
        println!("  {:<20} {:<18} {}{aliases}", c.name(), c.label(), c.summary());
    }
    Ok(())
}

fn cmd_eval(flags: &HashMap<String, String>) -> Result<()> {
    let ckpt = flags.get("ckpt").context("--ckpt required")?;
    let (model, provenance) = load_checkpoint_full(Path::new(ckpt))?;
    let corpus = flags.get("corpus").map(String::as_str).unwrap_or("wiki");
    let data = match corpus {
        "wiki" => experiments::wiki_dataset(),
        "c4" => experiments::c4_dataset(),
        other => bail!("unknown corpus {other}"),
    };
    match provenance.as_deref().map(PipelineSpec::parse) {
        Some(Ok(spec)) => println!("provenance: {}", spec.describe()),
        Some(Err(e)) => println!("provenance: unreadable ({e:#})"),
        None => println!("provenance: none recorded"),
    }
    println!(
        "{}: {corpus} test ppl {:.3} (density {:.3})",
        model.cfg.name,
        test_ppl(&model, &data),
        model.density()
    );
    Ok(())
}

fn cmd_generate(flags: &HashMap<String, String>) -> Result<()> {
    let ckpt = flags.get("ckpt").context("--ckpt required")?;
    let model = load_checkpoint(Path::new(ckpt))?;
    let v = Vocab::new();
    let prompt_text = flags.get("prompt").context("--prompt required")?;
    let prompt = v.encode(prompt_text);
    let max_new: usize = flags.get("max-new").map(String::as_str).unwrap_or("16").parse()?;
    let out = model.generate(&prompt, max_new);
    println!("{} {}", prompt_text, v.decode(&out));
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("model").map(String::as_str).unwrap_or("tiny-s");
    let flavour = flags.get("flavour").map(String::as_str).unwrap_or("dense");
    let n_requests: usize =
        flags.get("requests").map(String::as_str).unwrap_or("8").parse::<usize>()?.max(1);
    let max_new: usize = flags.get("max-new").map(String::as_str).unwrap_or("16").parse()?;
    let use_kv = !flags.contains_key("no-kv");
    // Scheduler knobs (DESIGN.md §6). max_batch 0 = backend lane cap.
    let max_batch: usize = flags.get("max-batch").map(String::as_str).unwrap_or("0").parse()?;
    // Paged-KV pool sizing (DESIGN.md §8): the pool holds the bytes of
    // this many contiguous max_seq lanes.
    let kv_lanes: usize =
        flags.get("kv-lanes").map(String::as_str).unwrap_or("4").parse::<usize>()?.max(1);
    // Range-checked at the CLI boundary: a bad knob is a usage error
    // here, not a panic (or silent nonsense) deep in the scheduler.
    let max_wait_ms: u64 = flags
        .get("max-wait-ms")
        .map(String::as_str)
        .unwrap_or("5")
        .parse()
        .context("--max-wait-ms must be a non-negative integer (milliseconds)")?;
    let queue_cap: usize = flags
        .get("queue-cap")
        .map(String::as_str)
        .unwrap_or("64")
        .parse()
        .context("--queue-cap must be a non-negative integer")?;
    // Chunked prefill (DESIGN.md §6): per-iteration token budget spent
    // advancing at most one in-flight prefill after the decode step.
    // 0 disables chunking (one monolithic backend call per prompt).
    let prefill_chunk: usize = flags
        .get("prefill-chunk")
        .map(String::as_str)
        .unwrap_or("512")
        .parse()
        .context("--prefill-chunk must be a non-negative integer (tokens; 0 = monolithic)")?;
    // Speculative decoding knobs (DESIGN.md §11).
    let speculate = flags.get("speculate").cloned();
    let draft_k: usize = flags
        .get("draft-k")
        .map(String::as_str)
        .unwrap_or("4")
        .parse()
        .context("--draft-k must be an integer")?;
    if !(1..=16).contains(&draft_k) {
        bail!("--draft-k must be in [1, 16], got {draft_k}");
    }
    // Sampling knobs (greedy by default).
    let temperature: f32 = flags.get("temperature").map(String::as_str).unwrap_or("0").parse()?;
    let top_k: usize = flags.get("top-k").map(String::as_str).unwrap_or("0").parse()?;
    // KV lifecycle knobs (DESIGN.md §10; native paged backend only).
    let evict = match flags.get("kv-evict").map(String::as_str) {
        None => pifa::runtime::EvictPolicyKind::default(),
        Some(s) => pifa::runtime::EvictPolicyKind::parse(s)
            .ok_or_else(|| anyhow!("unknown --kv-evict '{s}' (fifo|lru|freq)"))?,
    };
    let rank_frac: f64 = flags
        .get("kv-rank-frac")
        .map(String::as_str)
        .unwrap_or("0.5")
        .parse()
        .context("--kv-rank-frac must be a number in (0, 1]")?;
    if !(rank_frac > 0.0 && rank_frac <= 1.0) {
        bail!("--kv-rank-frac must be in (0, 1], got {rank_frac}");
    }
    let life = KvLifeConfig {
        evict,
        spill: flags.contains_key("kv-spill"),
        compress: flags.contains_key("kv-compress"),
        rank_frac,
    };
    // Router tier knobs (DESIGN.md §12; native backend only).
    let replicas: usize = flags
        .get("replicas")
        .map(String::as_str)
        .unwrap_or("1")
        .parse()
        .context("--replicas must be a positive integer")?;
    if replicas == 0 {
        bail!("--replicas must be at least 1");
    }
    let drain: Option<usize> = match flags.get("drain") {
        None => None,
        Some(s) => {
            let id: usize = s.parse().context("--drain must be a replica index")?;
            if id >= replicas {
                bail!("--drain {id} out of range for --replicas {replicas}");
            }
            Some(id)
        }
    };
    if drain.is_some() && replicas < 2 {
        bail!("--drain needs --replicas >= 2 (someone must keep serving)");
    }

    // Backend selection: PJRT when the runtime + artifacts are usable,
    // otherwise the Rust-native backend (same scheduler, no artifacts).
    let native = flags.contains_key("native")
        || match Engine::new(&artifact_dir()) {
            Ok(_) => false,
            Err(e) => {
                println!("PJRT unavailable ({e:#}); serving via the Rust-native backend");
                true
            }
        };

    let model = ensure_trained_model(name)?;
    let (prefill, decode, served) = match flavour {
        "dense" => (
            format!("{name}_dense_prefill_b1_t64"),
            format!("{name}_dense_decode_b1"),
            model.clone(),
        ),
        "pifa" => {
            let data = experiments::wiki_dataset();
            let method = flags.get("method").map(String::as_str).unwrap_or("mpifa");
            let density: f64 =
                flags.get("density").map(String::as_str).unwrap_or("0.55").parse()?;
            let output = compress_via_registry(&model, &data, method, density, flags)?;
            println!("pipeline: {}", output.spec.describe());
            let prefill = format!("{name}_pifa55_prefill_b1_t64");
            if !native {
                // Gate on artifact compatibility before spawning the
                // server: the lowered artifact fixes flavour + density.
                let manifest = Manifest::load(&artifact_dir())?;
                manifest
                    .get(&prefill)?
                    .kind
                    .validate_provenance(output.spec.artifact_flavour(), output.spec.density)
                    .context("compressed model incompatible with the pifa55 artifacts")?;
            }
            (prefill, format!("{name}_pifa55_decode_b1"), output.model)
        }
        other => bail!("unknown flavour {other}"),
    };
    let mode = if use_kv { GenerationMode::KvCache } else { GenerationMode::NoKvCache };
    // Draft model for --speculate: compress the BASE dense checkpoint
    // with the named registry method — the compressed/dense pair of the
    // same weights is the classic self-speculative setup (DESIGN.md
    // §11). Only the native KV-cache backend can verify/rollback;
    // anything else serves plain, loudly.
    let draft_model = match speculate.as_deref() {
        Some(method) if use_kv && native => {
            let data = experiments::wiki_dataset();
            let density: f64 =
                flags.get("density").map(String::as_str).unwrap_or("0.55").parse()?;
            let output = compress_via_registry(&model, &data, method, density, flags)?;
            println!("draft pipeline ({method}): {}", output.spec.describe());
            Some(output.model)
        }
        Some(method) => {
            println!(
                "--speculate {method} needs the native KV-cache backend; serving plain \
                 (drop --no-kv / PJRT artifacts to enable it)"
            );
            None
        }
        None => None,
    };
    let served_mem = served.memory_bytes_fp16();
    let scfg = SchedulerConfig {
        max_batch,
        max_wait: std::time::Duration::from_millis(max_wait_ms),
        queue_cap,
        prefill_chunk,
    };
    if replicas > 1 {
        if !native {
            bail!("--replicas needs the native backend (pass --native or drop the artifacts)");
        }
        if draft_model.is_some() {
            println!("--speculate is single-server only; the fleet serves plain");
        }
        let native_lanes = if use_kv { kv_lanes } else { kv_lanes.max(max_batch) };
        return serve_fleet(
            served, mode, life, scfg, replicas, drain, native_lanes, n_requests, max_new,
            temperature, top_k,
        );
    }
    let server = if native {
        let served = served.clone();
        // KV mode sizes the paged pool from --kv-lanes (the lane cap then
        // comes from the block watermark); no-KV mode has no pool, so the
        // lane slots must honour an explicit --max-batch directly.
        let native_lanes = if use_kv { kv_lanes } else { kv_lanes.max(max_batch) };
        match draft_model {
            Some(draft) => Server::spawn_speculative(
                move || {
                    let backend =
                        NativeBackend::new(served, mode, native_lanes).with_kvlife(life);
                    let engine = DraftEngine::new(
                        draft,
                        backend.lanes(),
                        SpecConfig { draft_k, ..SpecConfig::default() },
                    );
                    Ok((Box::new(backend) as Box<dyn DecodeBackend>, engine))
                },
                scfg,
            ),
            None => Server::spawn(
                move || {
                    Ok(Box::new(
                        NativeBackend::new(served, mode, native_lanes).with_kvlife(life),
                    ) as Box<dyn DecodeBackend>)
                },
                scfg,
            ),
        }
    } else {
        let served = served.clone();
        Server::spawn(
            move || {
                let mut pjrt = Engine::new(&artifact_dir())?;
                println!("PJRT platform: {}", pjrt.platform());
                let runner = ModelRunner::new(&mut pjrt, &served, &prefill, &decode)?;
                Ok(Box::new(PjrtBackend::new(pjrt, runner, mode)) as Box<dyn DecodeBackend>)
            },
            scfg,
        )
    };

    let v = Vocab::new();
    let sampling =
        SamplingParams { temperature, top_k, seed: 7, ..SamplingParams::default() };
    let mut handles = Vec::new();
    for i in 0..n_requests as u64 {
        // Mixed traffic: prompt lengths and budgets vary per request.
        let mut prompt = vec![v.id("the"), v.noun((i as usize) % 8, 3, false), v.verb(2, false)];
        if i % 2 == 0 {
            prompt.push(v.id("the"));
        }
        let req = GenRequest::new(i, prompt, max_new.saturating_sub(i as usize % 2).max(1))
            .with_sampling(sampling.clone());
        handles.push(server.submit(req)?);
    }
    // Stream the first request token-by-token; collect the rest.
    let first_stats = loop {
        match handles[0].next()? {
            Event::Token { token, .. } => {
                println!("req 0 [stream] += {}", v.decode(&[token]));
            }
            Event::Done(stats) => break stats,
            Event::Error(e) => return Err(e.into()),
        }
    };
    println!(
        "req 0: \"{}\" ({} tokens, ttft {:.1} ms, finish {:?})",
        v.decode(&first_stats.tokens),
        first_stats.tokens.len(),
        first_stats.ttft.as_secs_f64() * 1e3,
        first_stats.finish,
    );
    for h in handles.iter().skip(1) {
        match h.collect() {
            Ok(stats) => println!(
                "req {}: {} ({} tokens, {:.1} ms)",
                stats.id,
                v.decode(&stats.tokens),
                stats.tokens.len(),
                stats.latency.as_secs_f64() * 1e3
            ),
            Err(e) => println!("req {}: error: {e}", h.id),
        }
    }
    let metrics = server.shutdown()?;
    println!(
        "served {}/{} requests | throughput {:.1} tok/s | latency p50 {:.1} ms p95 {:.1} ms",
        metrics.completed,
        metrics.requests,
        metrics.throughput(),
        metrics.latency_percentile_ms(0.5),
        metrics.latency_percentile_ms(0.95),
    );
    println!(
        "ttft p50 {:.1} ms p95 {:.1} ms | itl p50 {:.2} ms p95 {:.2} ms | queue p95 {:.1} | occupancy p50 {:.0}% | weights {:.2} MB (fp16)",
        metrics.ttft_percentile_ms(0.5),
        metrics.ttft_percentile_ms(0.95),
        metrics.itl_percentile_ms(0.5),
        metrics.itl_percentile_ms(0.95),
        metrics.queue_depth_percentile(0.95),
        metrics.occupancy_percentile(0.5) * 100.0,
        served_mem as f64 / 1e6,
    );
    if metrics.tokens_drafted > 0 {
        println!(
            "spec: drafted {} accepted {} ({:.0}% acceptance) | fallbacks {}",
            metrics.tokens_drafted,
            metrics.tokens_accepted,
            metrics.spec_acceptance_rate() * 100.0,
            metrics.spec_fallbacks,
        );
    }
    if metrics.has_kv_pool() {
        println!(
            "kv: paged pool {} blocks (peak {} in use) | block util p50 {:.0}% p95 {:.0}% | prefix hit rate {:.0}% | cow forks {} | peak sessions {}",
            metrics.kv_blocks_total,
            metrics.kv_peak_blocks,
            metrics.block_util_percentile(0.5) * 100.0,
            metrics.block_util_percentile(0.95) * 100.0,
            metrics.prefix_hit_rate() * 100.0,
            metrics.kv_cow_copies,
            metrics.peak_active,
        );
        println!(
            "kv lifecycle ({}): idle at shutdown {} | evictions {} | spills {} | resumes {}",
            evict.name(),
            metrics.kv_idle_blocks,
            metrics.kv_evictions,
            metrics.spills,
            metrics.resumes,
        );
        if metrics.kv_spill_stored_bytes > 0 {
            println!(
                "kv spill arena: {:.1} KB raw -> {:.1} KB stored ({:.2}x compression)",
                metrics.kv_spill_raw_bytes as f64 / 1e3,
                metrics.kv_spill_stored_bytes as f64 / 1e3,
                metrics.kv_spill_raw_bytes as f64 / metrics.kv_spill_stored_bytes as f64,
            );
        }
    }
    Ok(())
}

/// `pifa serve --replicas N`: drive the same mixed traffic through the
/// multi-replica router tier (DESIGN.md §12) and print the per-replica
/// placements plus the fleet rollup. `--drain ID` drains one replica
/// halfway through submissions — the rolling-restart demo.
#[allow(clippy::too_many_arguments)]
fn serve_fleet(
    served: Transformer,
    mode: GenerationMode,
    life: KvLifeConfig,
    scheduler: SchedulerConfig,
    replicas: usize,
    drain: Option<usize>,
    lanes: usize,
    n_requests: usize,
    max_new: usize,
    temperature: f32,
    top_k: usize,
) -> Result<()> {
    let cfg = RouterConfig { replicas, scheduler, ..RouterConfig::default() };
    let mut router = Router::spawn(cfg, move |_id| {
        let m = served.clone();
        move || {
            Ok(Box::new(NativeBackend::new(m, mode, lanes).with_kvlife(life))
                as Box<dyn DecodeBackend>)
        }
    });
    let v = Vocab::new();
    let sampling = SamplingParams { temperature, top_k, seed: 7, ..SamplingParams::default() };
    let mut handles = Vec::new();
    for i in 0..n_requests as u64 {
        // A few recurring prompt families, so prefix-aware placement has
        // prefixes to route by.
        let mut prompt = vec![v.id("the"), v.noun((i as usize) % 4, 3, false), v.verb(2, false)];
        if i % 2 == 0 {
            prompt.push(v.id("the"));
        }
        let req = GenRequest::new(i, prompt, max_new.saturating_sub(i as usize % 2).max(1))
            .with_sampling(sampling.clone());
        let h = router.submit(req)?;
        match h.replica() {
            Some(r) => println!("req {i} -> replica {r}"),
            None => println!("req {i} -> unplaceable (all replicas draining or dead)"),
        }
        handles.push(h);
        if let Some(id) = drain {
            if i + 1 == (n_requests as u64).div_ceil(2) {
                router.drain(id)?;
                println!("draining replica {id}: active sessions finish, no new placements");
            }
        }
    }
    for h in &handles {
        match h.collect() {
            Ok(stats) => println!(
                "req {}: {} ({} tokens, {:.1} ms)",
                stats.id,
                v.decode(&stats.tokens),
                stats.tokens.len(),
                stats.latency.as_secs_f64() * 1e3
            ),
            Err(e) => println!("req {}: error: {e}", h.id()),
        }
    }
    let rm = router.shutdown()?;
    for (i, (m, s)) in rm.per_replica.iter().zip(&rm.replica_states).enumerate() {
        println!(
            "replica {i} [{}]: {} requests, {} completed, {} errors",
            s.name(),
            m.requests,
            m.completed,
            m.errors
        );
    }
    println!(
        "fleet: {}/{} completed | placements {} (prefix-routed {}, spilled {}, unplaceable {}) \
         | global prefix hit rate {:.0}%",
        rm.fleet.completed,
        rm.fleet.requests,
        rm.placements,
        rm.prefix_routed,
        rm.spilled,
        rm.unplaceable,
        rm.global_prefix_hit_rate() * 100.0,
    );
    println!(
        "fleet latency: ttft p50 {:.1} ms p95 {:.1} ms | itl p50 {:.2} ms p95 {:.2} ms | \
         throughput {:.1} tok/s",
        rm.fleet.ttft_percentile_ms(0.5),
        rm.fleet.ttft_percentile_ms(0.95),
        rm.fleet.itl_percentile_ms(0.5),
        rm.fleet.itl_percentile_ms(0.95),
        rm.fleet.throughput(),
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = artifact_dir();
    println!("artifact dir: {}", dir.display());
    match Engine::new(&dir) {
        Ok(eng) => {
            println!("PJRT platform: {}", eng.platform());
            let mut names: Vec<_> = eng.manifest.artifacts.keys().collect();
            names.sort();
            println!("{} artifacts:", names.len());
            for n in names {
                println!("  {n}");
            }
        }
        Err(e) => println!("engine unavailable ({e:#}); run `make artifacts`"),
    }
    Ok(())
}

fn cmd_bench_kernels(flags: &HashMap<String, String>) -> Result<()> {
    let smoke = flags.contains_key("smoke");
    let out = flags
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(pifa::bench::kernels::default_out);
    pifa::bench::kernels::run_cli(smoke, &out)
}

fn cmd_bench_serve(flags: &HashMap<String, String>) -> Result<()> {
    let smoke = flags.contains_key("smoke");
    let out = flags
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(pifa::bench::serve::default_out);
    let model = flags.get("model").map(String::as_str).unwrap_or("tiny-s");
    // Median-of-k discipline: the full grid defaults to 3 repetitions
    // per cell (bench-diff reads the count and narrows its noise band);
    // smoke keeps CI wall time down with 1.
    let default_reps = if smoke { "1" } else { "3" };
    let reps: usize =
        flags.get("reps").map(String::as_str).unwrap_or(default_reps).parse::<usize>()?.max(1);
    pifa::bench::serve::run_cli(smoke, &out, model, reps)
}

fn usage() -> ! {
    eprintln!(
        "usage: pifa <train|compress|methods|eval|generate|serve|tables|bench-kernels|\
         bench-serve|bench-diff|info> [--flags]\n\
         see rust/src/main.rs docs for details"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "train" => cmd_train(&flags),
        "compress" => cmd_compress(&flags),
        "methods" => cmd_methods(),
        "eval" => cmd_eval(&flags),
        "generate" => cmd_generate(&flags),
        "serve" => cmd_serve(&flags),
        "tables" => {
            let which = args.get(1).map(String::as_str).unwrap_or("all");
            pifa::bench::tablegen::run(which)
        }
        "bench-kernels" => cmd_bench_kernels(&flags),
        "bench-serve" => cmd_bench_serve(&flags),
        // bench-diff takes positional file paths, so it parses its own
        // argument list instead of going through `parse_flags`.
        "bench-diff" => pifa::bench::diff::run_cli(&args[1..]),
        "info" => cmd_info(),
        _ => usage(),
    }
}
