//! `pifa` — CLI for the PIFA/MPIFA reproduction.
//!
//! Subcommands (hand-rolled parsing; no clap in the offline crate set):
//!
//! ```text
//! pifa train    --model tiny-s [--out PATH]
//! pifa compress --model tiny-s --method mpifa --density 0.55 [--out PATH]
//! pifa eval     --ckpt PATH [--corpus wiki|c4]
//! pifa generate --ckpt PATH --prompt "the banlanba ..." [--max-new N]
//! pifa serve    --model tiny-s --flavour dense|pifa [--requests N] [--no-kv]
//! pifa tables   <fig1|tab2|tab3|...|all>   (same generators as cargo bench)
//! pifa info     — artifact + platform diagnostics
//! ```

use anyhow::{bail, Context, Result};
use pifa::bench::experiments::{
    self, compress_with_method, ensure_trained_model, test_ppl, Method,
};
use pifa::coordinator::{BatcherConfig, GenRequest, GenerationEngine, GenerationMode, Server};
use pifa::data::vocab::Vocab;
use pifa::model::serialize::{load_checkpoint, save_checkpoint};
use pifa::runtime::{Engine, ModelRunner};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "1".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn artifact_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn method_by_name(name: &str) -> Result<Method> {
    use pifa::baselines::prune::EspaceVariant as E;
    Ok(match name {
        "svd" => Method::Svd,
        "asvd" => Method::Asvd,
        "svdllm" | "svd-llm" => Method::SvdLlm,
        "w" => Method::SvdLlmW,
        "w+u" => Method::SvdLlmWU,
        "w+m" => Method::WPlusM,
        "mpifa" => Method::Mpifa,
        "mpifa-ns" | "mpifans" => Method::MpifaNs,
        "magnitude24" => Method::Magnitude24,
        "wanda24" => Method::Wanda24,
        "ria24" => Method::Ria24,
        "llm-pruner" | "llmpruner" => Method::LlmPruner,
        "espace-mse" => Method::Espace(E::Mse),
        "espace-mse-norm" => Method::Espace(E::MseNorm),
        "espace-go-mse" => Method::Espace(E::GoMse),
        "espace-go-mse-norm" => Method::Espace(E::GoMseNorm),
        other => bail!("unknown method '{other}'"),
    })
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("model").map(String::as_str).unwrap_or("tiny-s");
    let model = ensure_trained_model(name)?;
    if let Some(out) = flags.get("out") {
        save_checkpoint(&model, Path::new(out))?;
        println!("saved {out}");
    }
    let data = experiments::wiki_dataset();
    println!("{name}: test ppl {:.3}", test_ppl(&model, &data));
    Ok(())
}

fn cmd_compress(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("model").map(String::as_str).unwrap_or("tiny-s");
    let method = method_by_name(flags.get("method").map(String::as_str).unwrap_or("mpifa"))?;
    let density: f64 = flags.get("density").map(String::as_str).unwrap_or("0.55").parse()?;
    let model = ensure_trained_model(name)?;
    let data = experiments::wiki_dataset();
    let base = test_ppl(&model, &data);
    let t0 = std::time::Instant::now();
    let compressed = compress_with_method(&model, &data, method, density)?;
    let secs = t0.elapsed().as_secs_f64();
    let ppl = test_ppl(&compressed, &data);
    println!(
        "{name} {} @ density {density}: ppl {base:.3} -> {ppl:.3} (achieved density {:.3}, {secs:.1}s)",
        method.name(),
        compressed.density()
    );
    if let Some(out) = flags.get("out") {
        save_checkpoint(&compressed, Path::new(out))?;
        println!("saved {out}");
    }
    Ok(())
}

fn cmd_eval(flags: &HashMap<String, String>) -> Result<()> {
    let ckpt = flags.get("ckpt").context("--ckpt required")?;
    let model = load_checkpoint(Path::new(ckpt))?;
    let corpus = flags.get("corpus").map(String::as_str).unwrap_or("wiki");
    let data = match corpus {
        "wiki" => experiments::wiki_dataset(),
        "c4" => experiments::c4_dataset(),
        other => bail!("unknown corpus {other}"),
    };
    println!(
        "{}: {corpus} test ppl {:.3} (density {:.3})",
        model.cfg.name,
        test_ppl(&model, &data),
        model.density()
    );
    Ok(())
}

fn cmd_generate(flags: &HashMap<String, String>) -> Result<()> {
    let ckpt = flags.get("ckpt").context("--ckpt required")?;
    let model = load_checkpoint(Path::new(ckpt))?;
    let v = Vocab::new();
    let prompt_text = flags.get("prompt").context("--prompt required")?;
    let prompt = v.encode(prompt_text);
    let max_new: usize = flags.get("max-new").map(String::as_str).unwrap_or("16").parse()?;
    let out = model.generate(&prompt, max_new);
    println!("{} {}", prompt_text, v.decode(&out));
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags.get("model").map(String::as_str).unwrap_or("tiny-s");
    let flavour = flags.get("flavour").map(String::as_str).unwrap_or("dense");
    let n_requests: usize = flags.get("requests").map(String::as_str).unwrap_or("8").parse()?;
    let max_new: usize = flags.get("max-new").map(String::as_str).unwrap_or("16").parse()?;
    let use_kv = !flags.contains_key("no-kv");

    let model = ensure_trained_model(name)?;
    let (prefill, decode, served) = match flavour {
        "dense" => (
            format!("{name}_dense_prefill_b1_t64"),
            format!("{name}_dense_decode_b1"),
            model.clone(),
        ),
        "pifa" => {
            let data = experiments::wiki_dataset();
            let compressed = compress_with_method(&model, &data, Method::Mpifa, 0.55)?;
            (
                format!("{name}_pifa55_prefill_b1_t64"),
                format!("{name}_pifa55_decode_b1"),
                compressed,
            )
        }
        other => bail!("unknown flavour {other}"),
    };
    let mode = if use_kv { GenerationMode::KvCache } else { GenerationMode::NoKvCache };
    let served_mem = served.memory_bytes_fp16();
    let server = Server::spawn(
        move || {
            let mut pjrt = Engine::new(&artifact_dir())?;
            println!("PJRT platform: {}", pjrt.platform());
            let runner = ModelRunner::new(&mut pjrt, &served, &prefill, &decode)?;
            Ok((pjrt, GenerationEngine::new(runner, mode)))
        },
        BatcherConfig::default(),
    );

    let v = Vocab::new();
    let mut rxs = Vec::new();
    for i in 0..n_requests as u64 {
        let prompt = vec![v.id("the"), v.noun((i as usize) % 8, 3, false), v.verb(2, false)];
        rxs.push(server.submit(GenRequest::new(i, prompt, max_new))?);
    }
    for rx in rxs {
        let resp = rx.recv()?;
        println!(
            "req {}: {} ({} tokens, {:.1} ms)",
            resp.id,
            v.decode(&resp.tokens),
            resp.tokens.len(),
            resp.latency.as_secs_f64() * 1e3
        );
    }
    let metrics = server.shutdown()?;
    println!(
        "served {} requests | throughput {:.1} tok/s | p50 {:.1} ms | p95 {:.1} ms | weights {:.2} MB (fp16)",
        metrics.requests,
        metrics.throughput(),
        metrics.latency_percentile_ms(0.5),
        metrics.latency_percentile_ms(0.95),
        served_mem as f64 / 1e6,
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = artifact_dir();
    println!("artifact dir: {}", dir.display());
    match Engine::new(&dir) {
        Ok(eng) => {
            println!("PJRT platform: {}", eng.platform());
            let mut names: Vec<_> = eng.manifest.artifacts.keys().collect();
            names.sort();
            println!("{} artifacts:", names.len());
            for n in names {
                println!("  {n}");
            }
        }
        Err(e) => println!("engine unavailable ({e:#}); run `make artifacts`"),
    }
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: pifa <train|compress|eval|generate|serve|tables|info> [--flags]\n\
         see rust/src/main.rs docs for details"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "train" => cmd_train(&flags),
        "compress" => cmd_compress(&flags),
        "eval" => cmd_eval(&flags),
        "generate" => cmd_generate(&flags),
        "serve" => cmd_serve(&flags),
        "tables" => {
            let which = args.get(1).map(String::as_str).unwrap_or("all");
            pifa::bench::tablegen::run(which)
        }
        "info" => cmd_info(),
        _ => usage(),
    }
}
