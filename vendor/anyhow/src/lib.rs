//! Minimal vendored reimplementation of the `anyhow` API surface this
//! workspace uses (the offline crate set has no registry access).
//!
//! Supported: [`Error`] with a context chain, [`Result`], the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros, and the [`Context`] extension
//! trait on `Result` and `Option`. `Display` prints the outermost message;
//! the alternate form (`{:#}`) joins the whole cause chain with `: `;
//! `Debug` prints the chain anyhow-style (used by `fn main() -> Result<()>`).

use std::convert::Infallible;
use std::fmt::{self, Debug, Display};

/// A `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error wrapping a message and an optional cause chain.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { msg: message.to_string(), cause: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(c) = cur.cause.as_deref() {
            cur = c;
        }
        cur
    }
}

/// Iterator over an [`Error`]'s cause chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;
    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.cause.as_deref();
        Some(cur)
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.cause.is_some() {
            write!(f, "\n\nCaused by:")?;
            for (i, e) in self.chain().skip(1).enumerate() {
                write!(f, "\n    {i}: {}", e.msg)?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Flatten the std source chain into ours.
        let mut msgs: Vec<String> = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, cause: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chain_display() {
        let e: Error = Error::from(io_err()).context("opening config");
        assert_eq!(format!("{e}"), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: missing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let r = v.context("nothing there");
        assert_eq!(format!("{}", r.unwrap_err()), "nothing there");
        let v = Some(7u32);
        assert_eq!(v.context("unused").unwrap(), 7);
    }

    #[test]
    fn result_with_context_propagates() {
        fn inner() -> Result<()> {
            let r: std::result::Result<(), std::io::Error> = Err(io_err());
            r.with_context(|| format!("step {}", 3))?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).is_err());
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }
}
