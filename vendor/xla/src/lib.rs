//! Stub of the `xla` (xla_extension) bindings for the offline crate set.
//!
//! The host-side [`Literal`] type is a real implementation — shape-checked
//! construction, reshape, readback — because the runtime layer and its
//! tests use literals without a device. Everything that needs the native
//! PJRT runtime ([`PjRtClient::cpu`], compile, execute) returns a clear
//! [`Error`] instead, so binaries degrade gracefully on machines without
//! the XLA shared library (`pifa info` prints the reason; artifact-backed
//! tests skip themselves when `artifacts/` is absent).
//!
//! Swapping this stub for the real bindings is a one-line change in the
//! workspace manifest; the API surface below matches what `pifa::runtime`
//! calls.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type (implements `std::error::Error` so `anyhow` context
/// attaches cleanly).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new<M: Into<String>>(msg: M) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error::new(format!(
        "xla stub: {what} requires the native PJRT runtime (this build vendors the stub; \
         link the real xla_extension bindings to execute artifacts)"
    ))
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized {
    fn vec_literal(data: &[Self], dims: Vec<i64>) -> Literal;
    fn extract(lit: &Literal) -> Result<&[Self]>;
}

impl NativeType for f32 {
    fn vec_literal(data: &[Self], dims: Vec<i64>) -> Literal {
        Literal::F32 { data: data.to_vec(), dims }
    }
    fn extract(lit: &Literal) -> Result<&[Self]> {
        match lit {
            Literal::F32 { data, .. } => Ok(data),
            other => Err(Error::new(format!("literal is {}, wanted f32", other.type_name()))),
        }
    }
}

impl NativeType for i32 {
    fn vec_literal(data: &[Self], dims: Vec<i64>) -> Literal {
        Literal::I32 { data: data.to_vec(), dims }
    }
    fn extract(lit: &Literal) -> Result<&[Self]> {
        match lit {
            Literal::I32 { data, .. } => Ok(data),
            other => Err(Error::new(format!("literal is {}, wanted i32", other.type_name()))),
        }
    }
}

/// A host tensor (or tuple of tensors) in row-major order.
#[derive(Clone, Debug, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    fn type_name(&self) -> &'static str {
        match self {
            Literal::F32 { .. } => "f32",
            Literal::I32 { .. } => "i32",
            Literal::Tuple(_) => "tuple",
        }
    }

    /// Rank-1 literal over a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::vec_literal(data, vec![data.len() as i64])
    }

    /// Number of scalar elements (tuples: sum over elements).
    pub fn element_count(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(es) => es.iter().map(Literal::element_count).sum(),
        }
    }

    /// Reshape to `dims` (element count must match; `&[]` is a scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        match self {
            Literal::Tuple(_) => Err(Error::new("cannot reshape a tuple literal")),
            Literal::F32 { data, .. } => {
                if data.len() as i64 != want {
                    return Err(Error::new(format!(
                        "reshape: {} elements into {dims:?}",
                        data.len()
                    )));
                }
                Ok(Literal::F32 { data: data.clone(), dims: dims.to_vec() })
            }
            Literal::I32 { data, .. } => {
                if data.len() as i64 != want {
                    return Err(Error::new(format!(
                        "reshape: {} elements into {dims:?}",
                        data.len()
                    )));
                }
                Ok(Literal::I32 { data: data.clone(), dims: dims.to_vec() })
            }
        }
    }

    /// Copy the elements out.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self).map(|s| s.to_vec())
    }

    /// First element (scalar readback).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let s = T::extract(self)?;
        s.first().copied().ok_or_else(|| Error::new("empty literal"))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(es) => Ok(es),
            other => Err(Error::new(format!(
                "literal is {}, wanted tuple",
                other.type_name()
            ))),
        }
    }
}

/// Parsed HLO module (stub: parsing requires the native runtime).
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(unavailable(&format!("parsing HLO text {path}")))
    }
}

/// An XLA computation handle.
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation {}
    }
}

/// PJRT client (stub: construction always fails).
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_literal"))
    }
}

/// A compiled executable (stub: never constructible via the stub client).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }

    pub fn execute_b<T: Borrow<PjRtBuffer>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_construct_reshape_readback() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        let s = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
        assert!(s.to_vec::<f32>().is_err());
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32, 3])]);
        assert_eq!(t.element_count(), 3);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }

    #[test]
    fn runtime_paths_report_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("stub"));
    }
}
