//! Perf probe (EXPERIMENTS.md §Perf): measures the L3 GEMM roofline on
//! this machine and the PJRT dispatch overhead that bounds the serving
//! path at tiny-model scale.
//!
//! ```bash
//! cargo run --release --example perf_probe
//! ```

use pifa::bench::harness::bench_fn;
use pifa::linalg::{matmul, matmul_nt, Mat, Rng};
use pifa::pifa::{pivoting_factorization, rank_for_density_pifa, PivotStrategy};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(777);
    println!("== L3 GEMM roofline (f32, 1 thread unless auto-par kicks in) ==");
    for &d in &[128usize, 256, 512, 1024] {
        let a: Mat<f32> = Mat::randn(d, d, &mut rng);
        let b: Mat<f32> = Mat::randn(d, d, &mut rng);
        let r = bench_fn(&format!("gemm{d}"), 2, 7, || {
            let _ = matmul(&a, &b);
        });
        let gflops = 2.0 * (d as f64).powi(3) / r.median_secs() / 1e9;
        println!("  {d:>5}x{d:<5} {:>9.2} ms   {gflops:>6.2} GFLOP/s", r.median_ms());
    }

    println!("\n== PIFA layer vs dense layer (d=1024, tokens=128, rho=0.55) ==");
    let d = 1024;
    let tkn = 128;
    let x: Mat<f32> = Mat::randn(tkn, d, &mut rng);
    let w: Mat<f32> = Mat::randn(d, d, &mut rng);
    let t_dense = bench_fn("dense", 2, 7, || {
        let _ = matmul_nt(&x, &w);
    });
    let r = rank_for_density_pifa(d, d, 0.55);
    let wl: Mat<f32> = Mat::rand_low_rank(d, d, r, &mut rng);
    let layer = pivoting_factorization(&wl, r, PivotStrategy::QrColumnPivot)?;
    let t_pifa = bench_fn("pifa", 2, 7, || {
        let _ = layer.apply_rows(&x);
    });
    println!(
        "  dense {:.2} ms | PIFA {:.2} ms | speedup {:.2}x (FLOP-ideal {:.2}x)",
        t_dense.median_ms(),
        t_pifa.median_ms(),
        t_dense.median_secs() / t_pifa.median_secs(),
        (2.0 * (d * d) as f64) / (2.0 * r as f64 * (2 * d - r) as f64)
    );

    // PJRT dispatch overhead: smallest artifact, repeated execution.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        println!("\n== PJRT dispatch overhead (layer_dense_d256_t256) ==");
        let mut engine = pifa::runtime::Engine::new(&dir)?;
        let x = vec![0.1f32; 256 * 256];
        let w = vec![0.1f32; 256 * 256];
        let args = vec![
            pifa::runtime::loader::literal_f32(&x, &[256, 256])?,
            pifa::runtime::loader::literal_f32(&w, &[256, 256])?,
        ];
        let r = bench_fn("pjrt", 3, 15, || {
            let _ = engine.run("layer_dense_d256_t256", &args).unwrap();
        });
        let flops = 2.0 * 256f64 * 256.0 * 256.0;
        println!(
            "  per-call {:.3} ms ({:.2} GFLOP/s incl. host<->device copies)",
            r.median_ms(),
            flops / r.median_secs() / 1e9
        );
    }
    Ok(())
}
