//! Quickstart: Pivoting Factorization on a single weight matrix.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a low-rank matrix, runs PIFA (paper Algorithm 1), verifies the
//! factorization is lossless, and prints the memory/FLOP ledger the paper's
//! §3.3 derives.

use pifa::linalg::{matmul_nt, Mat, Rng};
use pifa::pifa::{
    dense_flops, dense_params, lowrank_flops, lowrank_params, pifa_flops, pifa_params,
    pivoting_factorization, PivotStrategy,
};

fn main() -> anyhow::Result<()> {
    let (m, n) = (512usize, 512usize);
    let r = 256; // rank = 50% of dimension — the paper's headline setting
    let mut rng = Rng::new(7);

    // Any low-rank matrix works — PIFA is a *meta* representation that
    // re-encodes the output of any low-rank pruning method.
    let w: Mat<f32> = Mat::rand_low_rank(m, n, r, &mut rng);

    let t0 = std::time::Instant::now();
    let layer = pivoting_factorization(&w, r, PivotStrategy::QrColumnPivot)?;
    println!("factorized {m}x{n} rank-{r} matrix in {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);

    // Losslessness (paper §3.2: "without inducing any loss").
    let rec_err = layer.reconstruct().rel_fro_err(&w);
    println!("reconstruction relative error: {rec_err:.2e}");
    assert!(rec_err < 1e-3, "PIFA must be lossless");

    // Inference equivalence: Y = W X via the PIFA layer.
    let x: Mat<f32> = Mat::randn(8, n, &mut rng);
    let y_dense = matmul_nt(&x, &w);
    let y_pifa = layer.apply_rows(&x);
    println!("inference relative error:      {:.2e}", y_pifa.rel_fro_err(&y_dense));

    // The §3.3 ledger.
    let b = 8;
    println!("\nparameters ({m}x{n}, r={r}):");
    println!("  dense     {:>12}", dense_params(m, n));
    println!("  low-rank  {:>12}  (r(m+n))", lowrank_params(m, n, r));
    println!(
        "  PIFA      {:>12}  (r(m+n) - r^2 + r; {:.1}% below low-rank)",
        pifa_params(m, n, r),
        100.0 * (1.0 - pifa_params(m, n, r) as f64 / lowrank_params(m, n, r) as f64)
    );
    println!("\nFLOPs per batch of {b}:");
    println!("  dense     {:>12}", dense_flops(m, n, b));
    println!("  low-rank  {:>12}", lowrank_flops(m, n, r, b));
    println!(
        "  PIFA      {:>12}  ({:.1}% below low-rank)",
        pifa_flops(m, n, r, b),
        100.0 * (1.0 - pifa_flops(m, n, r, b) as f64 / lowrank_flops(m, n, r, b) as f64)
    );
    Ok(())
}
