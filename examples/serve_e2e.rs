//! End-to-end driver (DESIGN.md "End-to-end validation"): train a stand-in
//! model, compress it with MPIFA, and serve *mixed* traffic through the
//! session scheduler — unequal prompt lengths and token budgets sharing
//! decode iterations, per-token streaming, a mid-stream cancellation, and
//! typed errors — reporting throughput, TTFT/ITL percentiles, and memory.
//!
//! Uses the PJRT backend when artifacts + the native runtime are
//! available, otherwise the Rust-native backend (same scheduler, same
//! protocol):
//!
//! ```bash
//! make artifacts                       # optional: enables the PJRT rows
//! PIFA_FAST=1 cargo run --release --example serve_e2e
//! ```

use pifa::bench::experiments::{ensure_trained_model, wiki_dataset};
use pifa::compress::registry;
use pifa::coordinator::{
    DecodeBackend, Event, GenRequest, GenerationMode, NativeBackend, PjrtBackend, SamplingParams,
    SchedulerConfig, Server, ServeError,
};
use pifa::data::vocab::Vocab;
use pifa::model::transformer::Transformer;
use pifa::runtime::{Engine, ModelRunner};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn spawn_server(
    artifact_dir: &Path,
    use_pjrt: bool,
    served: &Transformer,
    flavour: &str,
    cfg: SchedulerConfig,
) -> Server {
    let model = served.clone();
    if use_pjrt {
        let dir = artifact_dir.to_path_buf();
        let prefill = format!("tiny-s_{flavour}_prefill_b1_t64");
        let decode = format!("tiny-s_{flavour}_decode_b1");
        Server::spawn(
            move || {
                let mut pjrt = Engine::new(&dir)?;
                let runner = ModelRunner::new(&mut pjrt, &model, &prefill, &decode)?;
                Ok(Box::new(PjrtBackend::new(pjrt, runner, GenerationMode::KvCache))
                    as Box<dyn DecodeBackend>)
            },
            cfg,
        )
    } else {
        let lanes = cfg.max_batch;
        Server::spawn(
            move || {
                Ok(Box::new(NativeBackend::new(model, GenerationMode::KvCache, lanes))
                    as Box<dyn DecodeBackend>)
            },
            cfg,
        )
    }
}

fn main() -> anyhow::Result<()> {
    let artifact_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let use_pjrt = match Engine::new(&artifact_dir) {
        Ok(_) => true,
        Err(e) => {
            println!("PJRT unavailable ({e:#}); using the Rust-native backend\n");
            false
        }
    };

    let data = wiki_dataset();
    let model = ensure_trained_model("tiny-s")?;
    println!("compressing tiny-s with MPIFA @ 0.55 density...");
    let out = registry::compress("mpifa", &model, &data, 0.55)?;
    println!("pipeline: {}", out.spec.describe());
    let compressed = out.model;
    println!(
        "weights: dense {:.2} MB -> MPIFA {:.2} MB (fp16-accounted)\n",
        model.memory_bytes_fp16() as f64 / 1e6,
        compressed.memory_bytes_fp16() as f64 / 1e6,
    );

    let v = Vocab::new();
    let scfg = SchedulerConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(3),
        queue_cap: 32,
    };
    for (label, served, flavour) in
        [("dense", &model, "dense"), ("MPIFA 55%", &compressed, "pifa55")]
    {
        let server = spawn_server(&artifact_dir, use_pjrt, served, flavour, scfg.clone());
        // Mixed traffic: prompt lengths 3..=6 and budgets 8..=20 differ
        // per request — the scheduler coalesces them iteration-level.
        let n_requests = 6u64;
        let mut handles = Vec::new();
        for i in 0..n_requests {
            let mut prompt = vec![
                v.id("the"),
                v.noun(i as usize % 8, 2 + i as usize, false),
                v.verb(3, false),
            ];
            for j in 0..(i as usize % 4) {
                prompt.push(v.noun(j, 1, false));
            }
            let max_new = 8 + 2 * (i as usize % 7);
            let req = GenRequest::new(i, prompt, max_new).with_sampling(SamplingParams {
                temperature: if i % 2 == 0 { 0.0 } else { 0.7 },
                top_k: 8,
                seed: i,
                ..SamplingParams::default()
            });
            handles.push(server.submit(req)?);
        }

        // Request 0 streams token-by-token; request 1 is cancelled
        // mid-generation (its lane is reclaimed for queued work).
        let mut sample = String::new();
        handles[1].cancel();
        loop {
            match handles[0].next()? {
                Event::Token { token, .. } => {
                    sample.push(' ');
                    sample.push_str(&v.decode(&[token]));
                }
                Event::Done(stats) => {
                    println!(
                        "[{label}] req 0 streamed{sample} | ttft {:.1} ms | finish {:?}",
                        stats.ttft.as_secs_f64() * 1e3,
                        stats.finish
                    );
                    break;
                }
                Event::Error(e) => {
                    println!("[{label}] req 0 failed: {e}");
                    break;
                }
            }
        }
        let mut completed = 0usize;
        let mut cancelled = 0usize;
        for h in handles.iter().skip(1) {
            match h.collect() {
                Ok(_) => completed += 1,
                Err(ServeError::Cancelled) => cancelled += 1,
                Err(e) => println!("[{label}] req {} error: {e}", h.id),
            }
        }
        let metrics = server.shutdown()?;
        println!(
            "[{label}] {} reqs ({completed} collected, {cancelled} cancelled) | {:.1} tok/s | \
             latency p50 {:.0} ms p95 {:.0} ms",
            metrics.requests,
            metrics.throughput(),
            metrics.latency_percentile_ms(0.5),
            metrics.latency_percentile_ms(0.95),
        );
        println!(
            "[{label}] ttft p50 {:.1} ms | itl p50/p95 {:.2}/{:.2} ms | peak lanes {} | \
             occupancy p50 {:.0}%",
            metrics.ttft_percentile_ms(0.5),
            metrics.itl_percentile_ms(0.5),
            metrics.itl_percentile_ms(0.95),
            metrics.peak_active,
            metrics.occupancy_percentile(0.5) * 100.0,
        );
        if metrics.has_kv_pool() {
            println!(
                "[{label}] paged kv: {} blocks (peak {} in use) | block util p50 {:.0}% | \
                 prefix hit rate {:.0}% | cow forks {}",
                metrics.kv_blocks_total,
                metrics.kv_peak_blocks,
                metrics.block_util_percentile(0.5) * 100.0,
                metrics.prefix_hit_rate() * 100.0,
                metrics.kv_cow_copies,
            );
            println!(
                "[{label}] kv lifecycle: idle at shutdown {} | evictions {} | spills {} | \
                 resumes {}",
                metrics.kv_idle_blocks, metrics.kv_evictions, metrics.spills, metrics.resumes,
            );
        }
        println!();
    }
    println!("(Table 7's shape: MPIFA serves faster than dense at ~57% of the weight memory.)");
    Ok(())
}
