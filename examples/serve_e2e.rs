//! End-to-end driver (DESIGN.md "End-to-end validation"): train a stand-in
//! model, compress it with MPIFA, and serve batched requests through the
//! full three-layer stack — Rust coordinator → PJRT-compiled HLO (lowered
//! from the JAX/Pallas model) — reporting throughput, latency, and memory.
//!
//! ```bash
//! make artifacts                       # once
//! PIFA_FAST=1 cargo run --release --example serve_e2e
//! ```

use pifa::bench::experiments::{ensure_trained_model, wiki_dataset};
use pifa::compress::registry;
use pifa::coordinator::{BatcherConfig, GenRequest, GenerationEngine, GenerationMode, Server};
use pifa::data::vocab::Vocab;
use pifa::runtime::{Engine, ModelRunner};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let artifact_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        artifact_dir.join("manifest.txt").exists(),
        "run `make artifacts` first"
    );

    let data = wiki_dataset();
    let model = ensure_trained_model("tiny-s")?;
    println!("compressing tiny-s with MPIFA @ 0.55 density...");
    let out = registry::compress("mpifa", &model, &data, 0.55)?;
    println!("pipeline: {}", out.spec.describe());
    let compressed = out.model;
    println!(
        "weights: dense {:.2} MB -> MPIFA {:.2} MB (fp16-accounted)",
        model.memory_bytes_fp16() as f64 / 1e6,
        compressed.memory_bytes_fp16() as f64 / 1e6,
    );

    let v = Vocab::new();
    for (label, served, flavour) in [
        ("dense", model.clone(), "dense"),
        ("MPIFA 55%", compressed.clone(), "pifa55"),
    ] {
        let dir = artifact_dir.clone();
        let prefill = format!("tiny-s_{flavour}_prefill_b1_t64");
        let decode = format!("tiny-s_{flavour}_decode_b1");
        let served_clone = served.clone();
        let server = Server::spawn(
            move || {
                let mut pjrt = Engine::new(&dir)?;
                let runner = ModelRunner::new(&mut pjrt, &served_clone, &prefill, &decode)?;
                Ok((pjrt, GenerationEngine::new(runner, GenerationMode::KvCache)))
            },
            BatcherConfig::default(),
        );
        let n_requests = 6u64;
        let max_new = 16;
        let mut rxs = Vec::new();
        for i in 0..n_requests {
            let prompt = vec![
                v.id("the"),
                v.noun(i as usize % 8, 2 + i as usize, false),
                v.verb(3, false),
                v.id("the"),
            ];
            rxs.push(server.submit(GenRequest::new(i, prompt, max_new))?);
        }
        let mut sample = String::new();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv()?;
            if i == 0 {
                sample = v.decode(&resp.tokens);
            }
        }
        let metrics = server.shutdown()?;
        println!(
            "[{label}] {} reqs | {:.1} tok/s | p50 {:.0} ms | p95 {:.0} ms | sample: \"{}\"",
            metrics.requests,
            metrics.throughput(),
            metrics.latency_percentile_ms(0.5),
            metrics.latency_percentile_ms(0.95),
            sample
        );
    }
    println!("\n(Table 7's shape: MPIFA serves faster than dense at ~57% of the weight memory.)");
    Ok(())
}
