//! Train the tiny-s stand-in from scratch and log the loss curve
//! (the training half of the end-to-end validation; the curve is recorded
//! in EXPERIMENTS.md).
//!
//! ```bash
//! cargo run --release --example train_tiny [-- steps]
//! ```

use pifa::bench::experiments::{wiki_dataset, SEQ_LEN};
use pifa::data::batch::Split;
use pifa::data::corpus::unigram_ppl;
use pifa::eval::ppl::perplexity;
use pifa::linalg::Rng;
use pifa::model::config::ModelConfig;
use pifa::model::transformer::Transformer;
use pifa::train::trainer::{train, TrainConfig};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let data = wiki_dataset();
    let cfg = ModelConfig::tiny_s();
    let mut rng = Rng::new(42);
    let mut model = Transformer::new_random(&cfg, &mut rng);
    println!(
        "training {} ({} params, seq {}) for {steps} steps",
        cfg.name,
        cfg.param_count(),
        SEQ_LEN
    );
    let ppl0 = perplexity(&model, &data, Split::Val);
    println!("initial val ppl: {ppl0:.1}");

    let tc = TrainConfig { steps, log_every: 25, ..TrainConfig::default() };
    let report = train(&mut model, &data, &tc);

    let ppl1 = perplexity(&model, &data, Split::Test);
    let uni = unigram_ppl(&data.tokens, cfg.vocab);
    println!("\nloss curve (step, batch loss):");
    for (s, l) in &report.losses {
        println!("  {s:>5}  {l:.4}");
    }
    // Persist the curve for EXPERIMENTS.md.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir).ok();
    let csv: String = std::iter::once("step,loss".to_string())
        .chain(report.losses.iter().map(|(s, l)| format!("{s},{l}")))
        .collect::<Vec<_>>()
        .join("\n");
    std::fs::write(dir.join("train_loss_tiny_s.csv"), csv)?;
    println!(
        "\nfinal: test ppl {ppl1:.2} (unigram baseline {uni:.1}), {:.1}s total",
        report.elapsed_secs
    );
    anyhow::ensure!(ppl1 < uni, "model must beat the unigram baseline");
    println!("wrote results/train_loss_tiny_s.csv");
    Ok(())
}
