//! The bench gate in one sitting: run a single serving scenario through
//! the `bench-serve` library, self-diff it (passes), then inject a
//! synthetic 50% TTFT regression into the serialized report and watch
//! the gate fail — the exact mechanics CI's `bench-gate` job runs via
//! `pifa bench-serve --smoke` + `pifa bench-diff`.
//!
//! ```bash
//! cargo run --release --example bench_gate
//! ```

use pifa::bench::diff;
use pifa::bench::json::Json;
use pifa::bench::serve::{
    build_workload, catalogue, run_scenario, CellResult, ServeBenchReport,
};
use pifa::coordinator::GenerationMode;
use pifa::linalg::Rng;
use pifa::model::config::ModelConfig;
use pifa::model::transformer::Transformer;

fn main() -> anyhow::Result<()> {
    // A micro model keeps this demo in the sub-second range.
    let cfg = ModelConfig {
        name: "micro".into(),
        vocab: 64,
        dim: 24,
        n_layers: 2,
        n_heads: 2,
        ffn_hidden: 32,
        max_seq: 64,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
    };
    let mut rng = Rng::new(7);
    let model = Transformer::new_random(&cfg, &mut rng);

    // One scenario from the real catalogue, trimmed to demo size.
    let mut sc = catalogue(true)
        .into_iter()
        .find(|s| s.name == "poisson-short")
        .expect("catalogue always carries poisson-short");
    sc.requests = 6;
    println!(
        "scenario {}: {} requests, first arrivals at {:?}",
        sc.name,
        sc.requests,
        build_workload(&sc, cfg.vocab, cfg.max_seq, 0)
            .iter()
            .take(3)
            .map(|w| w.submit_at)
            .collect::<Vec<_>>()
    );

    let metrics = run_scenario(&model, GenerationMode::KvCache, &sc, 1)?;
    let report = ServeBenchReport {
        model: cfg.name.clone(),
        smoke: true,
        reps: 1,
        cells: vec![CellResult {
            scenario: sc.name.to_string(),
            method: "dense".to_string(),
            requests: sc.requests,
            metrics,
        }],
    };
    report.print_summary();

    // Self-diff: identical reports are always within noise.
    let parsed = Json::parse(&report.to_json())?;
    println!("\nschema: {}", diff::check_schema(&parsed)?);
    let self_diff = diff::compare_reports(&parsed, &parsed, 1.0)?;
    self_diff.print();
    assert!(!self_diff.failed(), "self-diff must pass");

    // Inject a 50% TTFT regression into the serialized candidate: the
    // gate must fail it even at the widest single-rep noise band.
    let ttft = report.cells[0].metric("ttft_p50_ms").unwrap_or(0.0);
    let injected = format!("\"ttft_p50_ms\": {:.6}", ttft * 1.5 + 1.0);
    let slow_text = report
        .to_json()
        .replace(&format!("\"ttft_p50_ms\": {ttft:.6}"), &injected);
    let slow = Json::parse(&slow_text)?;
    println!("\ninjecting a 50% TTFT regression into the candidate:");
    let gated = diff::compare_reports(&parsed, &slow, 1.0)?;
    gated.print();
    assert!(gated.failed(), "the injected regression must trip the gate");
    println!("\ngate verdict: FAILED as intended — this is the exit-1 path CI merges gate on");
    Ok(())
}
