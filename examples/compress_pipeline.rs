//! The full compression pipeline on a trained model: SVD-LLM vs MPIFA vs
//! the Table 5 ablation arms, at one density.
//!
//! ```bash
//! PIFA_FAST=1 cargo run --release --example compress_pipeline
//! ```
//!
//! Trains (or loads the cached) tiny-s stand-in, compresses it with each
//! method at 60% density, and prints perplexities + achieved densities —
//! a one-screen miniature of Tables 2/5.

use pifa::bench::experiments::{
    compress_with_method, ensure_trained_model, test_ppl, wiki_dataset, Method,
};

fn main() -> anyhow::Result<()> {
    let data = wiki_dataset();
    let model = ensure_trained_model("tiny-s")?;
    let base = test_ppl(&model, &data);
    println!("tiny-s dense: test ppl {base:.3}\n");
    println!(
        "{:<10} {:>10} {:>10} {:>8} {:>9}",
        "method", "ppl", "gap", "density", "seconds"
    );

    let density = 0.6;
    for method in [
        Method::Svd,
        Method::Asvd,
        Method::SvdLlmW,
        Method::SvdLlmWU,
        Method::WPlusM,
        Method::Mpifa,
    ] {
        let t0 = std::time::Instant::now();
        let compressed = compress_with_method(&model, &data, method, density)?;
        let secs = t0.elapsed().as_secs_f64();
        let ppl = test_ppl(&compressed, &data);
        println!(
            "{:<10} {:>10.3} {:>10.3} {:>8.3} {:>8.1}s",
            method.name(),
            ppl,
            ppl - base,
            compressed.density(),
            secs
        );
    }
    println!(
        "\nExpected ordering (paper Tables 2/5): SVD >> ASVD >= W >= W+U > W+M > MPIFA"
    );
    Ok(())
}
