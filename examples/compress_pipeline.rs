//! The full compression pipeline on a trained model: the registered
//! method presets at one density, resolved by name through the registry.
//!
//! ```bash
//! PIFA_FAST=1 cargo run --release --example compress_pipeline
//! ```
//!
//! Trains (or loads the cached) tiny-s stand-in, compresses it with each
//! method at 60% density, and prints perplexities + achieved densities —
//! a one-screen miniature of Tables 2/5 (plus the hybrid low-rank + 2:4
//! preset, which is just one more registry entry).

use pifa::bench::experiments::{ensure_trained_model, test_ppl, wiki_dataset};
use pifa::compress::registry;

fn main() -> anyhow::Result<()> {
    let data = wiki_dataset();
    let model = ensure_trained_model("tiny-s")?;
    let base = test_ppl(&model, &data);
    println!("tiny-s dense: test ppl {base:.3}\n");
    println!(
        "{:<12} {:>10} {:>10} {:>8} {:>9}",
        "method", "ppl", "gap", "density", "seconds"
    );

    let density = 0.6;
    for method in ["svd", "asvd", "w", "w+u", "w+m", "mpifa", "lowrank-s24"] {
        let compressor = registry::get(method)?;
        let t0 = std::time::Instant::now();
        let out = compressor.compress(&model, &data, density)?;
        let secs = t0.elapsed().as_secs_f64();
        let ppl = test_ppl(&out.model, &data);
        println!(
            "{:<12} {:>10.3} {:>10.3} {:>8.3} {:>8.1}s",
            compressor.label(),
            ppl,
            ppl - base,
            out.model.density(),
            secs
        );
    }
    println!(
        "\nExpected ordering (paper Tables 2/5): SVD >> ASVD >= W >= W+U > W+M > MPIFA"
    );
    println!("(methods available: {})", registry::names().join(", "));
    Ok(())
}
